//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace vendors
//! the thin slice of `anyhow` the project uses: [`Error`] (a message plus a
//! context chain), [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the [`anyhow!`] / [`bail!`] macros. Semantics mirror
//! the real crate where it matters here:
//!
//! * `{}` prints the outermost message; `{:#}` prints the whole chain
//!   separated by `": "` (what the tests match against);
//! * `?` converts any `std::error::Error + Send + Sync + 'static`,
//!   preserving its source chain;
//! * `.context(..)` / `.with_context(..)` wrap errors (and turn `None` into
//!   an error);
//! * [`Error::new`] keeps the typed error value, and
//!   [`Error::downcast_ref`] finds it again through any depth of added
//!   context — callers use this to branch on *typed* failures (e.g. the
//!   transport's `MeshError`) without string matching.
//!
//! [`Error`] deliberately does **not** implement `std::error::Error`, just
//! like the real `anyhow::Error` — that is what keeps the blanket `From`
//! impl coherent.

use std::any::Any;
use std::fmt;

/// A context-carrying error: the outermost message plus the chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// The original typed error value, when constructed from one
    /// ([`Error::new`] or the `From`/`?` conversion); recovered by
    /// [`Error::downcast_ref`].
    payload: Option<Box<dyn Any + Send + Sync>>,
}

/// `Result` defaulting to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
            payload: None,
        }
    }

    /// Build an error from a typed error value, keeping the value so
    /// [`Error::downcast_ref`] can recover it through later context wraps.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error {
            msg: error.to_string(),
            source: None,
            payload: Some(Box::new(error)),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
            payload: None,
        }
    }

    /// The typed error value of type `E` anywhere in the chain, if this
    /// error was built from one (mirrors `anyhow::Error::downcast_ref`,
    /// which looks through context).
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(p) = e.payload.as_ref().and_then(|p| p.downcast_ref::<E>()) {
                return Some(p);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std source chain into our own, innermost last.
        let mut msgs: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        // The outermost node is `e` itself; keep the typed value there so
        // `downcast_ref::<E>()` works like the real crate's.
        err.payload = Some(Box::new(e));
        err
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    /// Wrap the error (or `None`) with `context`.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($tt:tt)*) => {
        $crate::Error::msg(format!($($tt)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert_eq!(e.chain(), vec!["top", "mid", "root"]);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing file"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("while loading").unwrap_err();
        assert_eq!(format!("{e}"), "while loading");
        assert!(format!("{e:#}").contains("missing file"));

        let none: Option<u32> = None;
        let e = none.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "no value 7");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad thing {} at {}", "x", 42);
        assert_eq!(format!("{e}"), "bad thing x at 42");
        fn f() -> Result<()> {
            bail!("stopped at {}", 9);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "stopped at 9");
    }

    #[test]
    fn downcast_ref_finds_typed_value_through_context() {
        let e = Error::new(io_err())
            .context("while reading config")
            .context("run failed");
        let io = e.downcast_ref::<std::io::Error>().expect("typed value lost");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());

        // `?` conversion keeps the typed value too
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err().context("outer");
        assert!(e.downcast_ref::<std::io::Error>().is_some());

        // plain message errors carry no payload
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}
