//! API-compatible stub of the `xla` (PJRT) crate.
//!
//! The container building this workspace has no crates.io access and no XLA
//! toolchain, so the optional `pjrt` feature of `flash-sgd` links against
//! this stub instead: everything type-checks (so `--features pjrt` still
//! compiles and the engine code stays honest), but creating a client fails
//! with a clear message. To run against real PJRT, replace this path
//! dependency with the real `xla` crate.

use std::fmt;

/// Error type mirroring the real crate's (Display-able) error.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err() -> Error {
    Error(
        "xla stub: this build vendors an API stub of the `xla` crate; \
         swap in the real crate to use the PJRT backend"
            .to_string(),
    )
}

/// Element types used by the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(stub_err())
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _bytes: &[u8],
    ) -> Result<Self> {
        Err(stub_err())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub_err())
    }

    // The real crate's `to_tuple` consumes the literal; mirror it.
    #[allow(clippy::wrong_self_convention)]
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err())
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

/// PJRT client (stub). `cpu()` always fails, so no other stub method is
/// reachable at runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(stub_err())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("xla stub"));
    }
}
