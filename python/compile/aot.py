"""AOT pipeline: lower every Layer-2 entry point to HLO *text* + manifest.

Run once at build time (``make artifacts``); Python never appears on the
training path afterwards. For each model variant this emits:

    artifacts/<arch>_<entry>.hlo.txt      one HLO-text module per entry point
    artifacts/manifest.json               shapes/dtypes/param layout contract

HLO **text** — not ``lowered.compile().serialize()`` and not the serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Lowering uses ``return_tuple=True`` so every entry point returns a single
tuple; the Rust runtime unwraps it element-wise.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, resnet

# Per-arch executable matrix: (entry kind, per-worker batch, label smoothing).
# Mirrors Table 3: per-worker batches are 16 and 32 in the paper's
# experiments; the reduced-scale twins use the same 2x batch-size-control
# step. LS eps = 0.1 for experiments 2-4, 0.0 for the reference/exp-1 runs.
VARIANTS: Dict[str, dict] = {
    "tiny": {
        "kwargs": {},
        "grads": [(8, 0.0), (8, 0.1), (16, 0.0), (16, 0.1), (32, 0.0), (32, 0.1)],
        "eval_batch": 32,
    },
    "resnet20": {
        "kwargs": {},
        "grads": [(16, 0.0), (16, 0.1), (32, 0.0), (32, 0.1)],
        "eval_batch": 64,
    },
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_list(avals) -> List[dict]:
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def lower_entry(fn, arg_specs, out_path: str) -> dict:
    """Lower ``fn(*arg_specs)`` to HLO text at ``out_path``; return io spec."""
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *arg_specs)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    return {
        "file": os.path.basename(out_path),
        "inputs": _spec_list(arg_specs),
        "outputs": _spec_list(out_avals),
    }


def ls_tag(ls_eps: float) -> str:
    return f"ls{int(round(ls_eps * 100))}"


def build_arch(arch: str, spec: dict, out_dir: str, verbose: bool = True) -> dict:
    cfg = resnet.get_config(arch, **spec["kwargs"])
    template = jax.eval_shape(lambda: resnet.init_params(cfg, 0))
    leaves = jax.tree_util.tree_leaves(template)
    names = resnet.param_names(template)
    n_elems = sum(int(jnp.prod(jnp.array(l.shape))) for l in leaves) if leaves else 0
    bn_names = resnet.bn_layer_names(cfg)
    widths = resnet.bn_widths(cfg)

    entry: dict = {
        "config": {
            "name": cfg.name,
            "block": cfg.block,
            "stage_blocks": list(cfg.stage_blocks),
            "stage_widths": list(cfg.stage_widths),
            "num_classes": cfg.num_classes,
            "image_size": cfg.image_size,
            "image_channels": cfg.image_channels,
        },
        "params": [
            {"name": n, "shape": list(l.shape), "size": int(jnp.prod(jnp.array(l.shape)))}
            for n, l in zip(names, leaves)
        ],
        "total_params": int(n_elems),
        "bn_layers": [{"name": n, "width": widths[n]} for n in bn_names],
        "executables": {},
    }

    def emit(name: str, maker, *maker_args, **extra):
        fn, specs = maker(*maker_args)
        path = os.path.join(out_dir, f"{arch}_{name}.hlo.txt")
        if verbose:
            print(f"  lowering {arch}_{name} ...", flush=True)
        io = lower_entry(fn, specs, path)
        io.update(extra)
        entry["executables"][name] = io

    emit("init", model.make_init_step, cfg)
    emit("apply", model.make_apply_step, cfg)
    for batch, ls in spec["grads"]:
        emit(f"grad_b{batch}_{ls_tag(ls)}", model.make_grad_step, cfg, batch, ls,
             batch=batch, ls_eps=ls)
    eb = spec["eval_batch"]
    emit(f"eval_b{eb}", model.make_eval_step, cfg, eb, batch=eb)
    return entry


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--arches", default="tiny,resnet20",
                   help="comma-separated subset of: " + ",".join(VARIANTS))
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format_version": 1, "arches": {}}
    # Merge with an existing manifest so per-arch rebuilds don't clobber
    # other arches' entries.
    man_path_existing = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(man_path_existing):
        try:
            with open(man_path_existing) as f:
                old = json.load(f)
            if old.get("format_version") == 1:
                manifest["arches"].update(old.get("arches", {}))
        except (json.JSONDecodeError, OSError):
            pass
    for arch in args.arches.split(","):
        arch = arch.strip()
        if not arch:
            continue
        if arch not in VARIANTS:
            sys.exit(f"unknown arch {arch!r}; have {sorted(VARIANTS)}")
        print(f"[aot] building arch {arch}", flush=True)
        manifest["arches"][arch] = build_arch(
            arch, VARIANTS[arch], args.out_dir, verbose=not args.quiet
        )

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    total = sum(
        os.path.getsize(os.path.join(args.out_dir, e["file"]))
        for a in manifest["arches"].values()
        for e in a["executables"].values()
    )
    print(f"[aot] wrote {man_path} ({total/1e6:.1f} MB of HLO text)")


if __name__ == "__main__":
    main()
