"""Layer 2: functional ResNet family in pure JAX (paper §3.2).

Implements the model side of the paper's training stack:

  * ResNet-50 (He et al., CVPR 2016) — the paper's benchmark model, defined
    in full (bottleneck blocks, 224x224 input) and compile-tested.
  * CIFAR-scale ResNets (ResNet-8/20/32, basic blocks, 32x32 input) — the
    reduced-scale twins actually *trained* end-to-end on this CPU testbed
    (DESIGN.md §4 substitution table).

Batch normalisation follows the paper's "Batch Normalization without Moving
Average" (Akiba et al. [5]): training normalises with the *current batch*
statistics only and exports per-layer (mean, mean-of-squares) so that the
Rust coordinator can all-reduce them across workers in FP32 (paper §3.2) and
maintain the aggregate used at evaluation time. There are no moving-average
buffers in the parameter tree.

Everything is functional: parameters are a nested dict pytree whose flatten
order (``jax.tree_util`` sorted-key order) is the contract shared with the
AOT manifest and the Rust runtime.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
BnStats = Dict[str, jnp.ndarray]

BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    """Static architecture description (also serialised into the manifest)."""

    name: str
    block: str                  # "basic" | "bottleneck"
    stage_blocks: Tuple[int, ...]
    stage_widths: Tuple[int, ...]
    stem_width: int
    stem_kernel: int            # 3 for CIFAR stem, 7 for ImageNet stem
    stem_stride: int
    stem_pool: bool             # 3x3/2 max-pool after the stem (ImageNet)
    num_classes: int
    image_size: int
    image_channels: int = 3

    @property
    def input_shape(self) -> Tuple[int, int, int]:
        return (self.image_size, self.image_size, self.image_channels)


def cifar_resnet(depth: int, num_classes: int = 10, image_size: int = 32,
                 base_width: int = 16) -> ResNetConfig:
    """Standard CIFAR ResNet-(6n+2): 3 stages of n basic blocks."""
    assert (depth - 2) % 6 == 0, "CIFAR ResNet depth must be 6n+2"
    n = (depth - 2) // 6
    return ResNetConfig(
        name=f"resnet{depth}",
        block="basic",
        stage_blocks=(n, n, n),
        stage_widths=(base_width, 2 * base_width, 4 * base_width),
        stem_width=base_width,
        stem_kernel=3,
        stem_stride=1,
        stem_pool=False,
        num_classes=num_classes,
        image_size=image_size,
    )


def resnet50(num_classes: int = 1000, image_size: int = 224) -> ResNetConfig:
    """The paper's benchmark model: ImageNet ResNet-50 (bottleneck)."""
    return ResNetConfig(
        name="resnet50",
        block="bottleneck",
        stage_blocks=(3, 4, 6, 3),
        stage_widths=(256, 512, 1024, 2048),
        stem_width=64,
        stem_kernel=7,
        stem_stride=2,
        stem_pool=True,
        num_classes=num_classes,
        image_size=image_size,
    )


def tiny_resnet(num_classes: int = 10, image_size: int = 16) -> ResNetConfig:
    """ResNet-8 on small images — fast-test twin used across the test suites."""
    return ResNetConfig(
        name="tiny",
        block="basic",
        stage_blocks=(1, 1, 1),
        stage_widths=(8, 16, 32),
        stem_width=8,
        stem_kernel=3,
        stem_stride=1,
        stem_pool=False,
        num_classes=num_classes,
        image_size=image_size,
    )


BY_NAME = {
    "tiny": tiny_resnet,
    "resnet8": lambda **kw: cifar_resnet(8, **kw),
    "resnet20": lambda **kw: cifar_resnet(20, **kw),
    "resnet32": lambda **kw: cifar_resnet(32, **kw),
    "resnet50": resnet50,
}


def get_config(name: str, **kw) -> ResNetConfig:
    if name not in BY_NAME:
        raise KeyError(f"unknown arch {name!r}; have {sorted(BY_NAME)}")
    return BY_NAME[name](**kw)


# ---------------------------------------------------------------------------
# Initialisation (He-normal fan-in, paper init per [10])
# ---------------------------------------------------------------------------


def _he_normal(key, shape):
    """He-normal for HWIO conv kernels / (in, out) dense kernels."""
    fan_in = math.prod(shape[:-1])
    std = math.sqrt(2.0 / fan_in)
    return std * jax.random.normal(key, shape, dtype=jnp.float32)


def _conv_init(key, k, c_in, c_out):
    return {"w": _he_normal(key, (k, k, c_in, c_out))}


def _bn_init(c):
    return {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)}


def _dense_init(key, c_in, c_out):
    kw, kb = jax.random.split(key)
    return {
        "w": _he_normal(kw, (c_in, c_out)),
        "b": jnp.zeros((c_out,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _conv(params, x, stride=1):
    return jax.lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _batch_norm(params, x, name, train, bn_stats_in, bn_stats_out):
    """BN without moving average (paper §3.2, [5]).

    train=True: normalise with current-batch statistics and record
    (mean, mean(x^2)) per channel into ``bn_stats_out`` for the coordinator's
    FP32 cross-worker synchronisation.
    train=False: use the externally supplied synchronized statistics.
    """
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        sqmean = jnp.mean(jnp.square(x), axis=(0, 1, 2))
        bn_stats_out[name] = jnp.stack([mean, sqmean])
        var = jnp.maximum(sqmean - jnp.square(mean), 0.0)
    else:
        stats = bn_stats_in[name]
        mean, sqmean = stats[0], stats[1]
        var = jnp.maximum(sqmean - jnp.square(mean), 0.0)
    inv = jax.lax.rsqrt(var + BN_EPS)
    return params["gamma"] * (x - mean) * inv + params["beta"]


def _basic_block(params, x, stride, train, bn_in, bn_out, prefix):
    out = _conv(params["conv1"], x, stride)
    out = _batch_norm(params["bn1"], out, f"{prefix}.bn1", train, bn_in, bn_out)
    out = jax.nn.relu(out)
    out = _conv(params["conv2"], out, 1)
    out = _batch_norm(params["bn2"], out, f"{prefix}.bn2", train, bn_in, bn_out)
    if "proj" in params:
        x = _conv(params["proj"], x, stride)
        x = _batch_norm(params["proj_bn"], x, f"{prefix}.proj_bn", train, bn_in, bn_out)
    return jax.nn.relu(out + x)


def _bottleneck_block(params, x, stride, train, bn_in, bn_out, prefix):
    out = _conv(params["conv1"], x, 1)
    out = _batch_norm(params["bn1"], out, f"{prefix}.bn1", train, bn_in, bn_out)
    out = jax.nn.relu(out)
    out = _conv(params["conv2"], out, stride)
    out = _batch_norm(params["bn2"], out, f"{prefix}.bn2", train, bn_in, bn_out)
    out = jax.nn.relu(out)
    out = _conv(params["conv3"], out, 1)
    out = _batch_norm(params["bn3"], out, f"{prefix}.bn3", train, bn_in, bn_out)
    if "proj" in params:
        x = _conv(params["proj"], x, stride)
        x = _batch_norm(params["proj_bn"], x, f"{prefix}.proj_bn", train, bn_in, bn_out)
    return jax.nn.relu(out + x)


def _block_init(key, cfg: ResNetConfig, c_in: int, width: int, stride: int) -> Params:
    p: Params = {}
    keys = jax.random.split(key, 4)
    if cfg.block == "basic":
        p["conv1"] = _conv_init(keys[0], 3, c_in, width)
        p["bn1"] = _bn_init(width)
        p["conv2"] = _conv_init(keys[1], 3, width, width)
        p["bn2"] = _bn_init(width)
    else:
        mid = width // 4
        p["conv1"] = _conv_init(keys[0], 1, c_in, mid)
        p["bn1"] = _bn_init(mid)
        p["conv2"] = _conv_init(keys[1], 3, mid, mid)
        p["bn2"] = _bn_init(mid)
        p["conv3"] = _conv_init(keys[2], 1, mid, width)
        p["bn3"] = _bn_init(width)
    if stride != 1 or c_in != width:
        p["proj"] = _conv_init(keys[3], 1, c_in, width)
        p["proj_bn"] = _bn_init(width)
    return p


def init_params(cfg: ResNetConfig, seed) -> Params:
    """Initialise the full parameter tree. ``seed`` may be int or a PRNG key."""
    key = jax.random.PRNGKey(seed) if isinstance(seed, int) else seed
    keys = jax.random.split(key, 2 + len(cfg.stage_blocks))
    params: Params = {
        "stem": {
            "conv": _conv_init(keys[0], cfg.stem_kernel, cfg.image_channels,
                               cfg.stem_width),
            "bn": _bn_init(cfg.stem_width),
        }
    }
    c_in = cfg.stem_width
    for s, (n_blocks, width) in enumerate(zip(cfg.stage_blocks, cfg.stage_widths)):
        stage: Params = {}
        bkeys = jax.random.split(keys[1 + s], n_blocks)
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            stage[f"block{b}"] = _block_init(bkeys[b], cfg, c_in, width, stride)
            c_in = width
        params[f"stage{s}"] = stage
    params["head"] = _dense_init(keys[-1], c_in, cfg.num_classes)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def apply(cfg: ResNetConfig, params: Params, x: jnp.ndarray, *,
          train: bool, bn_stats: Optional[BnStats] = None
          ) -> Tuple[jnp.ndarray, BnStats]:
    """Forward pass. Returns (logits, bn_stats_out).

    train=True  → bn_stats_out maps layer name to stacked (mean, sqmean),
                  each row of width C (paper's FP32 BN-stat sync payload).
    train=False → ``bn_stats`` must hold the synchronized statistics;
                  bn_stats_out is empty.
    """
    bn_in: BnStats = bn_stats or {}
    bn_out: BnStats = {}
    block_fn = _basic_block if cfg.block == "basic" else _bottleneck_block

    out = _conv(params["stem"]["conv"], x, cfg.stem_stride)
    out = _batch_norm(params["stem"]["bn"], out, "stem.bn", train, bn_in, bn_out)
    out = jax.nn.relu(out)
    if cfg.stem_pool:
        out = jax.lax.reduce_window(
            out, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
        )

    for s, n_blocks in enumerate(cfg.stage_blocks):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            out = block_fn(
                params[f"stage{s}"][f"block{b}"], out, stride, train,
                bn_in, bn_out, f"stage{s}.block{b}",
            )

    out = jnp.mean(out, axis=(1, 2))
    logits = out @ params["head"]["w"] + params["head"]["b"]
    return logits, bn_out


# ---------------------------------------------------------------------------
# Flattening contract shared with the Rust runtime
# ---------------------------------------------------------------------------


def param_names(tree) -> List[str]:
    """Dotted names in ``tree_flatten`` order — the AOT manifest contract."""
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            parts.append(p.key if hasattr(p, "key") else str(p.idx))
        names.append(".".join(parts))
    return names


def bn_layer_names(cfg: ResNetConfig) -> List[str]:
    """All BN-stat layer names in sorted (flatten-contract) order."""
    names = ["stem.bn"]
    for s, n_blocks in enumerate(cfg.stage_blocks):
        for b in range(n_blocks):
            prefix = f"stage{s}.block{b}"
            names.append(f"{prefix}.bn1")
            names.append(f"{prefix}.bn2")
            if cfg.block == "bottleneck":
                names.append(f"{prefix}.bn3")
            first = b == 0
            c_in_changes = first and (
                s > 0 or cfg.stage_widths[0] != cfg.stem_width
            )
            if c_in_changes:
                names.append(f"{prefix}.proj_bn")
    return sorted(names)


def bn_widths(cfg: ResNetConfig) -> Dict[str, int]:
    """Channel width per BN-stat layer (manifest metadata)."""
    widths: Dict[str, int] = {"stem.bn": cfg.stem_width}
    for s, (n_blocks, width) in enumerate(zip(cfg.stage_blocks, cfg.stage_widths)):
        mid = width // 4 if cfg.block == "bottleneck" else width
        for b in range(n_blocks):
            prefix = f"stage{s}.block{b}"
            widths[f"{prefix}.bn1"] = mid
            widths[f"{prefix}.bn2"] = mid if cfg.block == "bottleneck" else width
            if cfg.block == "bottleneck":
                widths[f"{prefix}.bn3"] = width
            first = b == 0
            if first and (s > 0 or cfg.stage_widths[0] != cfg.stem_width):
                widths[f"{prefix}.proj_bn"] = width
    return widths
