"""Layer 2: AOT entry points — the functions lowered to HLO for the Rust runtime.

Four entry points per model variant (DESIGN.md §2). All tree arguments are
flattened to positional tensor lists in ``jax.tree_util`` order; the manifest
emitted by ``aot.py`` records the exact order/shapes/dtypes so the Rust side
can build literals without ever importing Python.

  init_step(seed)                       -> params..
  grad_step(params.., images, labels)   -> (loss, grads.., bn_stats..)
  apply_step(params.., momenta.., grads.., lr, momentum, wd)
                                        -> (params.., momenta..)
  eval_step(params.., bn_stats.., images, labels)
                                        -> (loss_sum, correct_count)

Division of labour with Layer 3 (the paper's structure): ``grad_step`` is the
per-worker compute; the Rust coordinator all-reduces grads (FP16 on the wire)
and BN stats (FP32) with the 2D-Torus collective; ``apply_step`` then applies
the Pallas LARS kernel with schedule scalars supplied by Rust each step.

The loss is label-smoothed softmax cross entropy (Pallas kernel, Layer 1);
weight decay enters through LARS, not the loss, following [10].
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import resnet
from .kernels import lars as lars_kernel
from .kernels import ls_softmax


def loss_fn(cfg, params, images, labels, ls_eps):
    """Mean label-smoothed CE over the batch + exported BN stats."""
    logits, bn_out = resnet.apply(cfg, params, images, train=True)
    per_row = ls_softmax.ls_softmax_xent(logits, labels, ls_eps)
    return jnp.mean(per_row), bn_out


def make_grad_step(cfg: resnet.ResNetConfig, batch: int, ls_eps: float):
    """(params.., images, labels) -> (loss, grads.., bn_stats..)."""
    template = jax.eval_shape(lambda: resnet.init_params(cfg, 0))
    treedef = jax.tree_util.tree_structure(template)
    n_params = treedef.num_leaves

    def grad_step(*args):
        param_leaves = args[:n_params]
        images, labels = args[n_params], args[n_params + 1]
        params = jax.tree_util.tree_unflatten(treedef, param_leaves)
        (loss, bn_out), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, images, labels, ls_eps), has_aux=True
        )(params)
        grad_leaves = jax.tree_util.tree_leaves(grads)
        bn_leaves = jax.tree_util.tree_leaves(bn_out)
        return (loss, *grad_leaves, *bn_leaves)

    img = jax.ShapeDtypeStruct((batch, *cfg.input_shape), jnp.float32)
    lab = jax.ShapeDtypeStruct((batch,), jnp.int32)
    param_specs = [
        jax.ShapeDtypeStruct(l.shape, l.dtype)
        for l in jax.tree_util.tree_leaves(template)
    ]
    return grad_step, (*param_specs, img, lab)


def make_apply_step(cfg: resnet.ResNetConfig, coeff: float = 0.01,
                    eps: float = 1e-6):
    """(params.., momenta.., grads.., lr, momentum, wd) -> (params.., momenta..).

    Applies the Layer-1 Pallas LARS kernel per tensor (layer-wise trust
    ratios). All optimizer arithmetic is FP32 (paper §3.2).
    """
    template = jax.eval_shape(lambda: resnet.init_params(cfg, 0))
    treedef = jax.tree_util.tree_structure(template)
    n = treedef.num_leaves

    def apply_step(*args):
        ws, ms, gs = args[:n], args[n:2 * n], args[2 * n:3 * n]
        lr, momentum, wd = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        new_w: List[jnp.ndarray] = []
        new_m: List[jnp.ndarray] = []
        for w, m, g in zip(ws, ms, gs):
            wn, mn = lars_kernel.lars_update(w, g, m, lr, momentum, wd,
                                             coeff, eps)
            new_w.append(wn)
            new_m.append(mn)
        return (*new_w, *new_m)

    leaves = jax.tree_util.tree_leaves(template)
    specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return apply_step, (*specs, *specs, *specs, scalar, scalar, scalar)


def make_eval_step(cfg: resnet.ResNetConfig, batch: int):
    """(params.., bn_stats.., images, labels) -> (loss_sum, correct).

    Uses the synchronized BN statistics maintained by the coordinator
    (BN-without-moving-average evaluation path). Plain (unsmoothed) CE for
    validation-loss reporting; accuracy is top-1 1-crop, as in the paper.
    """
    template = jax.eval_shape(lambda: resnet.init_params(cfg, 0))
    treedef = jax.tree_util.tree_structure(template)
    n = treedef.num_leaves
    bn_names = resnet.bn_layer_names(cfg)
    widths = resnet.bn_widths(cfg)

    def eval_step(*args):
        param_leaves = args[:n]
        bn_leaves = args[n:n + len(bn_names)]
        images, labels = args[n + len(bn_names)], args[n + len(bn_names) + 1]
        params = jax.tree_util.tree_unflatten(treedef, param_leaves)
        bn_stats = dict(zip(bn_names, bn_leaves))
        logits, _ = resnet.apply(cfg, params, images, train=False,
                                 bn_stats=bn_stats)
        per_row = ls_softmax.ls_softmax_xent(logits, labels, 0.0)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        )
        return jnp.sum(per_row), correct

    param_specs = [
        jax.ShapeDtypeStruct(l.shape, l.dtype)
        for l in jax.tree_util.tree_leaves(template)
    ]
    bn_specs = [
        jax.ShapeDtypeStruct((2, widths[name]), jnp.float32)
        for name in bn_names
    ]
    img = jax.ShapeDtypeStruct((batch, *cfg.input_shape), jnp.float32)
    lab = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return eval_step, (*param_specs, *bn_specs, img, lab)


def make_init_step(cfg: resnet.ResNetConfig):
    """(seed,) -> params.. — deterministic He init (paper init per [10])."""

    def init_step(seed):
        params = resnet.init_params(cfg, jax.random.PRNGKey(seed[0]))
        return tuple(jax.tree_util.tree_leaves(params))

    seed = jax.ShapeDtypeStruct((1,), jnp.int32)
    return init_step, (seed,)
