"""Pallas LARS optimizer kernels (Layer 1).

The paper (§3.2) runs LARS in FP32 because the trust ratio needs a wider
dynamic range than FP16. The hot spot is two phases per tensor:

  phase 1 — squared-norm reduction of w and g,
  phase 2 — elementwise momentum + weight update scaled by the trust ratio.

TPU adaptation (DESIGN.md §6): instead of CUDA block/warp reductions we tile
the flattened tensor over VMEM-sized blocks and exploit the *sequential* TPU
grid to accumulate partial norms into a (1,1) output ref — the TPU-native
reduction idiom. Phase 2 is a plain VPU-elementwise pass over the same block
schedule. Both kernels are lowered with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls; see DESIGN.md).

Block size: 64K floats = 256 KiB per operand; phase 2 touches 3 inputs +
2 outputs ≈ 1.25 MiB of VMEM — comfortably under the ~16 MiB VMEM budget
even with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flattened-tensor block width (number of f32 lanes per grid step).
BLOCK = 65536


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad_to_block(x, n_pad):
    """Pad flat vector to a BLOCK multiple so the grid tiles exactly."""
    if n_pad == 0:
        return x
    return jnp.pad(x, (0, n_pad))


# ---------------------------------------------------------------------------
# Phase 1: fused squared-norm reduction of (w, g)
# ---------------------------------------------------------------------------


def _sqnorm_kernel(w_ref, g_ref, out_ref):
    """Accumulate [sum(w^2), sum(g^2)] into out_ref of shape (1, 2).

    The TPU grid executes sequentially, so read-modify-write accumulation
    across grid steps is well-defined; step 0 initialises the accumulator.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    partial = jnp.stack([jnp.sum(w * w), jnp.sum(g * g)]).reshape(1, 2)
    out_ref[...] += partial


def sqnorms(w, g, *, block=BLOCK, interpret=True):
    """Fused [||w||^2, ||g||^2] over arbitrarily-shaped tensors.

    Returns a (2,) float32 array. Zero-padding the tail block is exact for a
    squared-norm reduction.
    """
    wf = w.reshape(-1).astype(jnp.float32)
    gf = g.reshape(-1).astype(jnp.float32)
    n = wf.shape[0]
    blk = min(block, max(n, 1))
    pad = _ceil_div(n, blk) * blk - n
    wf = _pad_to_block(wf, pad)
    gf = _pad_to_block(gf, pad)
    grid = wf.shape[0] // blk
    out = pl.pallas_call(
        _sqnorm_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=interpret,
    )(wf, gf)
    return out.reshape(2)


# ---------------------------------------------------------------------------
# Phase 2: elementwise momentum + weight update
# ---------------------------------------------------------------------------


def _apply_kernel(w_ref, g_ref, m_ref, s_ref, w_out_ref, m_out_ref):
    """m' = momentum*m + scale*(g + wd*w);  w' = w - m'.

    s_ref is a (1, 3) scalar block: [scale, momentum, weight_decay], where
    scale = lr * trust_ratio was computed from the phase-1 norms.
    """
    scale = s_ref[0, 0]
    momentum = s_ref[0, 1]
    wd = s_ref[0, 2]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    m_new = momentum * m + scale * (g + wd * w)
    w_out_ref[...] = w - m_new
    m_out_ref[...] = m_new


def lars_apply(w, g, m, scale, momentum, weight_decay, *, block=BLOCK, interpret=True):
    """Elementwise LARS update with a precomputed scalar scale = lr*trust.

    Shapes are preserved; all arithmetic in FP32 (paper §3.2).
    """
    shape = w.shape
    wf = w.reshape(-1).astype(jnp.float32)
    gf = g.reshape(-1).astype(jnp.float32)
    mf = m.reshape(-1).astype(jnp.float32)
    n = wf.shape[0]
    blk = min(block, max(n, 1))
    pad = _ceil_div(n, blk) * blk - n
    wf = _pad_to_block(wf, pad)
    gf = _pad_to_block(gf, pad)
    mf = _pad_to_block(mf, pad)
    grid = wf.shape[0] // blk
    scalars = jnp.stack(
        [
            jnp.asarray(scale, jnp.float32),
            jnp.asarray(momentum, jnp.float32),
            jnp.asarray(weight_decay, jnp.float32),
        ]
    ).reshape(1, 3)
    w_new, m_new = pl.pallas_call(
        _apply_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid * blk,), jnp.float32),
            jax.ShapeDtypeStruct((grid * blk,), jnp.float32),
        ],
        interpret=interpret,
    )(wf, gf, mf, scalars)
    return w_new[:n].reshape(shape), m_new[:n].reshape(shape)


# ---------------------------------------------------------------------------
# Full per-tensor LARS step (phase 1 + trust ratio + phase 2)
# ---------------------------------------------------------------------------


def lars_update(w, g, m, lr, momentum, weight_decay, coeff=0.01, eps=1e-6,
                *, block=BLOCK, interpret=True):
    """One LARS step for a single tensor via the Pallas kernels.

    Semantics identical to ``ref.lars_update``; returns (w', m').
    """
    norms = sqnorms(w, g, block=block, interpret=interpret)
    w_norm = jnp.sqrt(norms[0])
    g_norm = jnp.sqrt(norms[1])
    trust = coeff * w_norm / (g_norm + weight_decay * w_norm + eps)
    trust = jnp.where((w_norm > 0.0) & (g_norm > 0.0), trust, 1.0)
    scale = jnp.asarray(lr, jnp.float32) * trust
    return lars_apply(
        w, g, m, scale, momentum, weight_decay, block=block, interpret=interpret
    )


def lars_update_tree(params, grads, momenta, lr, momentum, weight_decay,
                     coeff=0.01, eps=1e-6, *, interpret=True):
    """LARS over a pytree of tensors (layer-wise trust ratios, paper §3.2)."""
    leaves_w, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(momenta)
    new_w, new_m = [], []
    for w, g, m in zip(leaves_w, leaves_g, leaves_m):
        wn, mn = lars_update(
            w, g, m, lr, momentum, weight_decay, coeff, eps, interpret=interpret
        )
        new_w.append(wn)
        new_m.append(mn)
    return (
        jax.tree_util.tree_unflatten(treedef, new_w),
        jax.tree_util.tree_unflatten(treedef, new_m),
    )
