"""Pallas momentum-SGD kernel (Layer 1 baseline optimizer).

The non-LARS comparison point (Goyal et al. [1] style, L2 folded into the
update). Shares the flattened-block schedule of the LARS kernel but needs no
norm phase — a pure single-pass VPU-elementwise update, which is exactly the
structural difference the LARS ablation measures: LARS costs one extra
reduction pass over the parameters.

Mirrors ``rust/src/optim/sgd.rs`` and is checked against
``ref``-equivalent arithmetic in ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lars import BLOCK, _ceil_div, _pad_to_block


def _sgd_kernel(w_ref, g_ref, m_ref, s_ref, w_out_ref, m_out_ref):
    """m' = momentum*m + lr*(g + wd*w);  w' = w - m'.

    s_ref is a (1, 3) scalar block: [lr, momentum, weight_decay].
    """
    lr = s_ref[0, 0]
    momentum = s_ref[0, 1]
    wd = s_ref[0, 2]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    m_new = momentum * m + lr * (g + wd * w)
    w_out_ref[...] = w - m_new
    m_out_ref[...] = m_new


def sgd_update(w, g, m, lr, momentum, weight_decay, *, block=BLOCK,
               interpret=True):
    """One momentum-SGD step for a single tensor. Returns (w', m')."""
    shape = w.shape
    wf = w.reshape(-1).astype(jnp.float32)
    gf = g.reshape(-1).astype(jnp.float32)
    mf = m.reshape(-1).astype(jnp.float32)
    n = wf.shape[0]
    blk = min(block, max(n, 1))
    pad = _ceil_div(n, blk) * blk - n
    wf = _pad_to_block(wf, pad)
    gf = _pad_to_block(gf, pad)
    mf = _pad_to_block(mf, pad)
    grid = wf.shape[0] // blk
    scalars = jnp.stack(
        [
            jnp.asarray(lr, jnp.float32),
            jnp.asarray(momentum, jnp.float32),
            jnp.asarray(weight_decay, jnp.float32),
        ]
    ).reshape(1, 3)
    w_new, m_new = pl.pallas_call(
        _sgd_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid * blk,), jnp.float32),
            jax.ShapeDtypeStruct((grid * blk,), jnp.float32),
        ],
        interpret=interpret,
    )(wf, gf, mf, scalars)
    return w_new[:n].reshape(shape), m_new[:n].reshape(shape)
