"""Pallas fused label-smoothed softmax cross-entropy (Layer 1).

Label smoothing (paper §2.1, Szegedy et al. 2016) is one of the paper's two
large-mini-batch stabilisers. The fused kernel computes, per logit row z and
integer label y with smoothing eps and K classes:

    t     = (1-eps) * onehot(y) + eps/K
    loss  = logsumexp(z) - <t, z>
    dz    = softmax(z) - t            (backward)

TPU adaptation (DESIGN.md §6): rows are blocked over the batch dimension and
the full class axis stays resident in VMEM (K=1000 → 4 KiB per row, trivially
fitting); max/exp/sum/smoothed-NLL fuse into a single VPU pass. The true-label
logit is selected with a broadcasted-iota compare instead of a gather — the
TPU-friendly formulation. Forward and backward share the row-block schedule
and are tied together with ``jax.custom_vjp`` so ``jax.grad`` through the
Layer-2 model lowers both kernels into the AOT HLO.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 rows x 1024 classes x 4B = 512 KiB resident.
ROW_BLOCK = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _fwd_kernel(z_ref, y_ref, eps_ref, loss_ref):
    """Per-row smoothed CE. z: (BR, K) f32, y: (BR,) i32, loss: (BR,)."""
    z = z_ref[...].astype(jnp.float32)
    y = y_ref[...]
    eps = eps_ref[0, 0]
    k = z.shape[-1]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1)) + zmax[:, 0]
    # True-label logit via iota-compare (no gather on TPU).
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = (cols == y[:, None]).astype(jnp.float32)
    true_logit = jnp.sum(onehot * z, axis=-1)
    mean_logit = jnp.sum(z, axis=-1) / k
    # <t, z> = (1-eps)*z_y + eps*mean(z)
    loss_ref[...] = lse - (1.0 - eps) * true_logit - eps * mean_logit


def _bwd_kernel(z_ref, y_ref, eps_ref, dloss_ref, dz_ref):
    """dz = dloss[:, None] * (softmax(z) - t)."""
    z = z_ref[...].astype(jnp.float32)
    y = y_ref[...]
    eps = eps_ref[0, 0]
    dloss = dloss_ref[...].astype(jnp.float32)
    k = z.shape[-1]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - zmax)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = (cols == y[:, None]).astype(jnp.float32)
    t = (1.0 - eps) * onehot + eps / k
    dz_ref[...] = dloss[:, None] * (p - t)


def _row_pad(x, rows_padded):
    pad = rows_padded - x.shape[0]
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width)


def _fwd_call(logits, labels, ls_eps, *, interpret=True):
    b, k = logits.shape
    br = min(ROW_BLOCK, b)
    rows = _ceil_div(b, br) * br
    z = _row_pad(logits.astype(jnp.float32), rows)
    y = _row_pad(labels.astype(jnp.int32), rows)
    eps = jnp.asarray(ls_eps, jnp.float32).reshape(1, 1)
    loss = pl.pallas_call(
        _fwd_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
    )(z, y, eps)
    return loss[:b]


def _bwd_call(logits, labels, ls_eps, dloss, *, interpret=True):
    b, k = logits.shape
    br = min(ROW_BLOCK, b)
    rows = _ceil_div(b, br) * br
    z = _row_pad(logits.astype(jnp.float32), rows)
    y = _row_pad(labels.astype(jnp.int32), rows)
    dl = _row_pad(dloss.astype(jnp.float32), rows)
    eps = jnp.asarray(ls_eps, jnp.float32).reshape(1, 1)
    dz = pl.pallas_call(
        _bwd_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), jnp.float32),
        interpret=interpret,
    )(z, y, eps, dl)
    return dz[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ls_softmax_xent(logits, labels, ls_eps):
    """Per-row label-smoothed softmax cross entropy, shape [B] float32.

    Differentiable w.r.t. ``logits`` (the Pallas backward kernel supplies the
    VJP); ``labels`` are integer class ids.
    """
    return _fwd_call(logits, labels, ls_eps)


def _vjp_fwd(logits, labels, ls_eps):
    return _fwd_call(logits, labels, ls_eps), (logits, labels)


def _vjp_bwd(ls_eps, res, dloss):
    logits, labels = res
    dz = _bwd_call(logits, labels, ls_eps, dloss)
    return dz.astype(logits.dtype), None


ls_softmax_xent.defvjp(_vjp_fwd, _vjp_bwd)
