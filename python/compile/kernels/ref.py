"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
checked against the function of the same name here (pytest + hypothesis in
``python/tests/``), and the Rust-side reference optimizer
(``rust/src/optim/lars.rs``) mirrors ``lars_update`` bit-for-bit in FP32.

Formulas follow the paper's sources:
  * LARS — You, Gitman, Ginsburg, "Large Batch Training of Convolutional
    Networks" (arXiv:1708.03888), with the paper's defaults coeff=0.01,
    eps=1e-6, and FP32 trust-ratio arithmetic (paper §3.2).
  * Label smoothing — Szegedy et al. (CVPR 2016), as used in paper §2.1.
"""

from __future__ import annotations

import jax.numpy as jnp


def lars_trust_ratio(w, g, weight_decay, coeff, eps):
    """Layer-wise LARS trust ratio (FP32).

    local_lr = coeff * ||w|| / (||g|| + weight_decay * ||w|| + eps)

    Degenerate layers (||w|| == 0 or ||g|| == 0, e.g. zero-init BN beta at
    step 0) fall back to trust ratio 1.0, matching NNL / NVIDIA LARS
    implementations.
    """
    w = w.astype(jnp.float32)
    g = g.astype(jnp.float32)
    w_norm = jnp.sqrt(jnp.sum(w * w))
    g_norm = jnp.sqrt(jnp.sum(g * g))
    trust = coeff * w_norm / (g_norm + weight_decay * w_norm + eps)
    ok = (w_norm > 0.0) & (g_norm > 0.0)
    return jnp.where(ok, trust, 1.0)


def lars_update(w, g, m, lr, momentum, weight_decay, coeff=0.01, eps=1e-6):
    """One LARS step for a single tensor. Returns (w', m').

    m' = momentum * m + (lr * trust) * (g + weight_decay * w)
    w' = w - m'
    """
    w32 = w.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    m32 = m.astype(jnp.float32)
    trust = lars_trust_ratio(w32, g32, weight_decay, coeff, eps)
    scaled = (lr * trust) * (g32 + weight_decay * w32)
    m_new = momentum * m32 + scaled
    w_new = w32 - m_new
    return w_new.astype(w.dtype), m_new.astype(m.dtype)


def smoothed_targets(labels, num_classes, ls_eps):
    """(1-eps)*onehot + eps/K soft targets, float32, shape [B, K]."""
    onehot = jnp.eye(num_classes, dtype=jnp.float32)[labels]
    return (1.0 - ls_eps) * onehot + ls_eps / num_classes


def ls_softmax_xent(logits, labels, ls_eps):
    """Label-smoothed softmax cross entropy, per-row. Returns [B] float32.

    loss_i = logsumexp(z_i) - sum_k t_ik * z_ik
    with t = smoothed_targets(labels).
    """
    z = logits.astype(jnp.float32)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1)) + zmax[..., 0]
    t = smoothed_targets(labels, z.shape[-1], ls_eps)
    return lse - jnp.sum(t * z, axis=-1)


def ls_softmax_xent_grad(logits, labels, ls_eps):
    """d(loss_i)/d(z) for the per-row loss above: softmax(z) - t. [B, K]."""
    z = logits.astype(jnp.float32)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - zmax)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    t = smoothed_targets(labels, z.shape[-1], ls_eps)
    return p - t
