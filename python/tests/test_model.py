"""Layer-2 tests: ResNet forward/grad shapes, BN-stat export, entry points."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, resnet


@pytest.fixture(scope="module")
def tiny():
    return resnet.tiny_resnet()


@pytest.fixture(scope="module")
def tiny_params(tiny):
    return resnet.init_params(tiny, 0)


def test_init_shapes_deterministic(tiny):
    p1 = resnet.init_params(tiny, 42)
    p2 = resnet.init_params(tiny, 42)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(a, b)


def test_forward_shapes(tiny, tiny_params):
    x = jnp.zeros((4, 16, 16, 3))
    logits, bn = resnet.apply(tiny, tiny_params, x, train=True)
    assert logits.shape == (4, 10)
    assert set(bn.keys()) == set(resnet.bn_layer_names(tiny))
    widths = resnet.bn_widths(tiny)
    for name, stats in bn.items():
        assert stats.shape == (2, widths[name]), name


def test_eval_uses_supplied_bn_stats(tiny, tiny_params):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 3)).astype(np.float32))
    _, bn = resnet.apply(tiny, tiny_params, x, train=True)
    logits_train, _ = resnet.apply(tiny, tiny_params, x, train=True)
    logits_eval, out = resnet.apply(tiny, tiny_params, x, train=False, bn_stats=bn)
    # same batch stats -> identical normalisation
    np.testing.assert_allclose(logits_eval, logits_train, rtol=1e-4, atol=1e-4)
    assert out == {}


def test_bn_stats_are_batch_moments(tiny, tiny_params):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 3)).astype(np.float32))
    _, bn = resnet.apply(tiny, tiny_params, x, train=True)
    stats = bn["stem.bn"]
    # mean of squares >= square of mean (Jensen)
    assert np.all(np.asarray(stats[1]) >= np.asarray(stats[0]) ** 2 - 1e-5)


def test_grads_finite_and_matching_shapes(tiny, tiny_params):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(4,)).astype(np.int32))
    (loss, _), grads = jax.value_and_grad(
        lambda p: model.loss_fn(tiny, p, x, y, 0.1), has_aux=True
    )(tiny_params)
    assert np.isfinite(float(loss))
    for g, w in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(tiny_params)):
        assert g.shape == w.shape
        assert np.isfinite(np.asarray(g)).all()


def test_grad_step_entry_point(tiny):
    fn, specs = model.make_grad_step(tiny, batch=4, ls_eps=0.1)
    args = [jnp.zeros(s.shape, s.dtype) for s in specs]
    # real params, random data
    params = resnet.init_params(tiny, 0)
    leaves = jax.tree_util.tree_leaves(params)
    args[:len(leaves)] = leaves
    rng = np.random.default_rng(3)
    args[len(leaves)] = jnp.asarray(rng.normal(size=specs[len(leaves)].shape).astype(np.float32))
    args[len(leaves) + 1] = jnp.asarray(rng.integers(0, 10, size=(4,)).astype(np.int32))
    out = fn(*args)
    n_bn = len(resnet.bn_layer_names(tiny))
    assert len(out) == 1 + len(leaves) + n_bn
    assert np.isfinite(float(out[0]))


def test_apply_step_entry_point_matches_ref(tiny):
    from compile.kernels import ref

    fn, specs = model.make_apply_step(tiny)
    params = resnet.init_params(tiny, 0)
    leaves = jax.tree_util.tree_leaves(params)
    n = len(leaves)
    rng = np.random.default_rng(4)
    grads = [jnp.asarray(rng.normal(size=l.shape).astype(np.float32)) * 0.01
             for l in leaves]
    momenta = [jnp.zeros_like(l) for l in leaves]
    out = fn(*leaves, *momenta, *grads,
             jnp.float32(0.1), jnp.float32(0.9), jnp.float32(5e-5))
    assert len(out) == 2 * n
    for i in (0, n - 1):
        w_ref, m_ref = ref.lars_update(leaves[i], grads[i], momenta[i],
                                       0.1, 0.9, 5e-5)
        np.testing.assert_allclose(out[i], w_ref, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(out[n + i], m_ref, rtol=2e-5, atol=2e-5)


def test_eval_step_entry_point(tiny):
    fn, specs = model.make_eval_step(tiny, batch=8)
    params = resnet.init_params(tiny, 0)
    leaves = jax.tree_util.tree_leaves(params)
    bn_names = resnet.bn_layer_names(tiny)
    widths = resnet.bn_widths(tiny)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32))
    # feed real batch stats so eval normalisation is sane
    _, bn = resnet.apply(tiny, params, x, train=True)
    bn_leaves = [bn[nm] for nm in bn_names]
    loss_sum, correct = fn(*leaves, *bn_leaves, x, y)
    assert np.isfinite(float(loss_sum))
    assert 0.0 <= float(correct) <= 8.0


def test_init_step_entry_point(tiny):
    fn, specs = model.make_init_step(tiny)
    out = fn(jnp.asarray([7], jnp.int32))
    template = resnet.init_params(tiny, jax.random.PRNGKey(7))
    for got, want in zip(out, jax.tree_util.tree_leaves(template)):
        np.testing.assert_array_equal(got, want)


def test_training_reduces_loss_tiny_e2e(tiny):
    """Smoke: a few LARS steps on a fixed batch reduce the smoothed loss."""
    from compile.kernels import lars as lars_kernel

    params = resnet.init_params(tiny, 0)
    momenta = jax.tree_util.tree_map(jnp.zeros_like, params)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(16, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(16,)).astype(np.int32))

    @jax.jit
    def step(params, momenta):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss_fn(tiny, p, x, y, 0.1), has_aux=True
        )(params)
        new_p, new_m = lars_kernel.lars_update_tree(
            params, grads, momenta, 2.0, 0.9, 5e-5
        )
        return new_p, new_m, loss

    losses = []
    for _ in range(6):
        params, momenta, loss = step(params, momenta)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_resnet50_definition_compiles():
    """The paper's benchmark model: shape-check the full graph (no exec)."""
    cfg = resnet.resnet50(image_size=64)  # smaller spatial dims, same graph
    template = jax.eval_shape(lambda: resnet.init_params(cfg, 0))
    leaves = jax.tree_util.tree_leaves(template)
    total = sum(int(np.prod(l.shape)) for l in leaves)
    # ~25.5M params for 1000 classes regardless of image size
    assert 25.0e6 < total < 26.0e6, total
    logits, bn = jax.eval_shape(
        lambda p: resnet.apply(cfg, p, jnp.zeros((2, 64, 64, 3)), train=True),
        template,
    )
    assert logits.shape == (2, 1000)
    assert len(bn) == len(resnet.bn_layer_names(cfg)) == 53


def test_param_names_stable_order(tiny):
    params = resnet.init_params(tiny, 0)
    names = resnet.param_names(params)
    assert len(names) == len(set(names)) == len(jax.tree_util.tree_leaves(params))
    assert names == sorted(names) or names  # flatten order is the contract
    # spot-check a few known names
    assert "head.b" in names and "stem.conv.w" in names
