"""AOT pipeline tests: manifest contract + HLO text round-trip sanity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, resnet

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_has_parseable_header(tmp_path):
    cfg = resnet.tiny_resnet()
    fn, specs = model.make_init_step(cfg)
    out = tmp_path / "init.hlo.txt"
    io = aot.lower_entry(fn, specs, str(out))
    text = out.read_text()
    assert text.startswith("HloModule")
    assert "entry_computation_layout" in text
    assert io["inputs"][0]["shape"] == [1]
    assert io["inputs"][0]["dtype"] == "int32"


def test_ls_tag():
    assert aot.ls_tag(0.0) == "ls0"
    assert aot.ls_tag(0.1) == "ls10"
    assert aot.ls_tag(0.05) == "ls5"


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_matches_model_contract():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["format_version"] == 1
    for arch, entry in man["arches"].items():
        cfg = resnet.get_config(arch)
        template = jax.eval_shape(lambda: resnet.init_params(cfg, 0))
        leaves = jax.tree_util.tree_leaves(template)
        names = resnet.param_names(template)
        assert [p["name"] for p in entry["params"]] == names
        assert [tuple(p["shape"]) for p in entry["params"]] == [
            tuple(l.shape) for l in leaves
        ]
        assert entry["total_params"] == sum(int(np.prod(l.shape)) for l in leaves)
        bn_names = resnet.bn_layer_names(cfg)
        assert [b["name"] for b in entry["bn_layers"]] == bn_names
        # every executable file exists
        for name, ex in entry["executables"].items():
            path = os.path.join(ART, ex["file"])
            assert os.path.exists(path), path
            n_in = len(ex["inputs"])
            n_out = len(ex["outputs"])
            if name == "init":
                assert n_in == 1 and n_out == len(leaves)
            elif name == "apply":
                assert n_in == 3 * len(leaves) + 3
                assert n_out == 2 * len(leaves)
            elif name.startswith("grad_"):
                assert n_in == len(leaves) + 2
                assert n_out == 1 + len(leaves) + len(bn_names)
            elif name.startswith("eval_"):
                assert n_in == len(leaves) + len(bn_names) + 2
                assert n_out == 2


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_grad_variants_cover_batch_size_control():
    """Table 3: batch-size control needs >=2 per-worker batch variants."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for arch, entry in man["arches"].items():
        batches = {
            ex["batch"]
            for name, ex in entry["executables"].items()
            if name.startswith("grad_")
        }
        assert len(batches) >= 2, f"{arch}: need >=2 grad batch sizes, got {batches}"
