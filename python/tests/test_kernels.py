"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes/parameters; assert_allclose against ref.
This is the core correctness signal for everything the AOT pipeline bakes
into the HLO artifacts.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lars, ls_softmax, ref

F32 = np.float32


def arr(rng, shape, scale=1.0, dtype=F32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype) * scale)


# ---------------------------------------------------------------------------
# LARS
# ---------------------------------------------------------------------------

shapes = st.sampled_from(
    [(7,), (64,), (65,), (128, 3), (3, 3, 4, 8), (1,), (257,), (16, 16)]
)


@settings(max_examples=25, deadline=None)
@given(
    shape=shapes,
    lr=st.floats(1e-4, 40.0),
    momentum=st.floats(0.0, 0.999),
    wd=st.floats(0.0, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_lars_update_matches_ref(shape, lr, momentum, wd, seed):
    rng = np.random.default_rng(seed)
    w, g, m = arr(rng, shape), arr(rng, shape), arr(rng, shape, 0.1)
    w_ref, m_ref = ref.lars_update(w, g, m, lr, momentum, wd)
    w_pal, m_pal = lars.lars_update(w, g, m, lr, momentum, wd, block=64)
    np.testing.assert_allclose(w_pal, w_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(m_pal, m_ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 1000), block=st.sampled_from([32, 64, 256, 65536]),
       seed=st.integers(0, 2**31 - 1))
def test_sqnorms_blocked_vs_dense(n, block, seed):
    rng = np.random.default_rng(seed)
    w, g = arr(rng, (n,)), arr(rng, (n,))
    out = lars.sqnorms(w, g, block=block)
    np.testing.assert_allclose(out[0], jnp.sum(w * w), rtol=1e-5)
    np.testing.assert_allclose(out[1], jnp.sum(g * g), rtol=1e-5)


def test_lars_zero_weight_falls_back_to_unit_trust():
    w = jnp.zeros((10,))
    g = jnp.ones((10,))
    m = jnp.zeros((10,))
    w_ref, m_ref = ref.lars_update(w, g, m, 0.5, 0.9, 1e-4)
    w_pal, m_pal = lars.lars_update(w, g, m, 0.5, 0.9, 1e-4)
    # trust ratio 1.0 -> plain momentum SGD step
    np.testing.assert_allclose(w_pal, w_ref, atol=1e-7)
    np.testing.assert_allclose(m_pal, -w_pal, atol=1e-7)


def test_lars_zero_grad_falls_back_to_unit_trust():
    rng = np.random.default_rng(0)
    w, m = arr(rng, (31,)), arr(rng, (31,), 0.01)
    g = jnp.zeros((31,))
    w_ref, m_ref = ref.lars_update(w, g, m, 0.5, 0.9, 0.0)
    w_pal, m_pal = lars.lars_update(w, g, m, 0.5, 0.9, 0.0)
    np.testing.assert_allclose(w_pal, w_ref, rtol=1e-6)
    np.testing.assert_allclose(m_pal, m_ref, rtol=1e-6)


def test_lars_trust_ratio_formula():
    rng = np.random.default_rng(3)
    w, g = arr(rng, (100,)), arr(rng, (100,))
    wd, coeff, eps = 5e-5, 0.01, 1e-6
    t = ref.lars_trust_ratio(w, g, wd, coeff, eps)
    wn = float(jnp.linalg.norm(w))
    gn = float(jnp.linalg.norm(g))
    assert abs(float(t) - coeff * wn / (gn + wd * wn + eps)) < 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lars_update_tree(seed):
    rng = np.random.default_rng(seed)
    params = {"a": arr(rng, (8, 4)), "b": {"c": arr(rng, (5,))}}
    grads = {"a": arr(rng, (8, 4)), "b": {"c": arr(rng, (5,))}}
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_w, new_m = lars.lars_update_tree(params, grads, mom, 0.1, 0.9, 1e-4)
    for path in (("a",), ("b", "c")):
        w = params[path[0]] if len(path) == 1 else params["b"]["c"]
        g = grads[path[0]] if len(path) == 1 else grads["b"]["c"]
        nw = new_w[path[0]] if len(path) == 1 else new_w["b"]["c"]
        nm = new_m[path[0]] if len(path) == 1 else new_m["b"]["c"]
        rw, rm = ref.lars_update(w, g, jnp.zeros_like(w), 0.1, 0.9, 1e-4)
        np.testing.assert_allclose(nw, rw, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(nm, rm, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Label-smoothed softmax cross entropy
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 200),
    k=st.integers(2, 1000),
    eps=st.sampled_from([0.0, 0.05, 0.1, 0.3]),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_ls_softmax_fwd_matches_ref(b, k, eps, scale, seed):
    rng = np.random.default_rng(seed)
    z = arr(rng, (b, k), scale)
    y = jnp.asarray(rng.integers(0, k, size=(b,)).astype(np.int32))
    got = ls_softmax.ls_softmax_xent(z, y, eps)
    want = ref.ls_softmax_xent(z, y, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 64),
    k=st.integers(2, 200),
    eps=st.sampled_from([0.0, 0.1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ls_softmax_grad_matches_ref(b, k, eps, seed):
    rng = np.random.default_rng(seed)
    z = arr(rng, (b, k), 3.0)
    y = jnp.asarray(rng.integers(0, k, size=(b,)).astype(np.int32))
    got = jax.grad(lambda zz: jnp.sum(ls_softmax.ls_softmax_xent(zz, y, eps)))(z)
    want = ref.ls_softmax_xent_grad(z, y, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_ls_softmax_zero_eps_is_plain_xent():
    rng = np.random.default_rng(1)
    z = arr(rng, (17, 10), 2.0)
    y = jnp.asarray(rng.integers(0, 10, size=(17,)).astype(np.int32))
    got = ls_softmax.ls_softmax_xent(z, y, 0.0)
    want = -jax.nn.log_softmax(z)[jnp.arange(17), y]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ls_softmax_numerically_stable_at_large_logits():
    z = jnp.asarray([[1e4, -1e4, 0.0]], jnp.float32)
    y = jnp.asarray([0], jnp.int32)
    got = ls_softmax.ls_softmax_xent(z, y, 0.1)
    assert np.isfinite(np.asarray(got)).all()
    want = ref.ls_softmax_xent(z, y, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_ls_softmax_loss_increases_with_wrong_label():
    z = jnp.asarray([[5.0, 0.0, 0.0]], jnp.float32)
    right = ls_softmax.ls_softmax_xent(z, jnp.asarray([0], jnp.int32), 0.1)
    wrong = ls_softmax.ls_softmax_xent(z, jnp.asarray([1], jnp.int32), 0.1)
    assert float(wrong[0]) > float(right[0])


def test_smoothed_targets_sum_to_one():
    t = ref.smoothed_targets(jnp.asarray([0, 3], jnp.int32), 10, 0.1)
    np.testing.assert_allclose(jnp.sum(t, axis=-1), jnp.ones(2), rtol=1e-6)
    assert abs(float(t[0, 0]) - 0.91) < 1e-6
    assert abs(float(t[0, 1]) - 0.01) < 1e-6


# ---------------------------------------------------------------------------
# Momentum-SGD baseline kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    shape=shapes,
    lr=st.floats(1e-4, 5.0),
    momentum=st.floats(0.0, 0.99),
    wd=st.floats(0.0, 1e-2),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_kernel_matches_formula(shape, lr, momentum, wd, seed):
    from compile.kernels import sgd

    rng = np.random.default_rng(seed)
    w, g, m = arr(rng, shape), arr(rng, shape), arr(rng, shape, 0.1)
    w_new, m_new = sgd.sgd_update(w, g, m, lr, momentum, wd, block=64)
    m_want = momentum * m + lr * (g + wd * w)
    w_want = w - m_want
    np.testing.assert_allclose(m_new, m_want, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(w_new, w_want, rtol=2e-5, atol=2e-6)


def test_sgd_equals_lars_at_unit_trust():
    from compile.kernels import sgd

    # zero grads -> LARS trust falls back to 1.0 -> identical updates
    rng = np.random.default_rng(0)
    w = arr(rng, (65,))
    g = jnp.zeros((65,))
    m = arr(rng, (65,), 0.1)
    w_s, m_s = sgd.sgd_update(w, g, m, 0.3, 0.9, 0.0)
    w_l, m_l = lars.lars_update(w, g, m, 0.3, 0.9, 0.0)
    np.testing.assert_allclose(w_s, w_l, rtol=1e-6)
    np.testing.assert_allclose(m_s, m_l, rtol=1e-6)
