//! Figure 2 walkthrough: the 2D-Torus all-reduce on a 2×2 grid, step by
//! step, with real data through the real collective — plus the topology
//! rendering of Figure 1.
//!
//!     cargo run --release --example torus_demo

use std::thread;

use flashsgd::collectives::primitives::{
    chunk_offsets, ring_all_gather, ring_all_reduce, ring_reduce_scatter, Wire,
};
use flashsgd::collectives::{Collective, Mesh, TorusAllReduce};
use flashsgd::repro;

fn main() {
    println!("{}", repro::figure1(4, 2));

    println!("Figure 2: 2D-Torus all-reduce on a 2x2 grid, element by element");
    let torus = TorusAllReduce::new(2, 2);
    let n_elems = 4usize;

    // Each GPU starts with its own vector, as in the paper's figure.
    let initial: Vec<Vec<f32>> = (0..4)
        .map(|r| (0..n_elems).map(|i| (10 * (r + 1) + i) as f32).collect())
        .collect();
    for (r, v) in initial.iter().enumerate() {
        println!("  GPU{r} (x={}, y={}) starts with {:?}", r % 2, r / 2, v);
    }
    let want: Vec<f32> = (0..n_elems)
        .map(|i| initial.iter().map(|v| v[i]).sum())
        .collect();
    println!("  expected sum: {want:?}\n");

    // Phase-by-phase trace on rank threads.
    let eps = Mesh::new(4);
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let mut buf = initial[ep.rank()].clone();
            thread::spawn(move || {
                let rank = ep.rank();
                let row: Vec<usize> = vec![rank / 2 * 2, rank / 2 * 2 + 1];
                let col: Vec<usize> = vec![rank % 2, rank % 2 + 2];
                let x_pos = rank % 2;
                let y_pos = rank / 2;

                // Step 1: horizontal reduce-scatter.
                let owned =
                    ring_reduce_scatter(&mut ep, &row, x_pos, &mut buf, Wire::F32, 0).unwrap();
                let offs = chunk_offsets(buf.len(), 2);
                let own_chunk = buf[offs[owned]..offs[owned + 1]].to_vec();
                let after1 = format!(
                    "GPU{rank} after H reduce-scatter: owns chunk {owned} = {own_chunk:?}"
                );

                // Step 2: vertical all-reduce of the owned chunk.
                ring_all_reduce(
                    &mut ep,
                    &col,
                    y_pos,
                    &mut buf[offs[owned]..offs[owned + 1]],
                    Wire::F32,
                    100,
                )
                .unwrap();
                let after2 = format!(
                    "GPU{rank} after V all-reduce:     chunk {owned} = {:?}",
                    &buf[offs[owned]..offs[owned + 1]]
                );

                // Step 3: horizontal all-gather.
                ring_all_gather(&mut ep, &row, x_pos, &mut buf, Wire::F32, 200).unwrap();
                let after3 = format!("GPU{rank} after H all-gather:     {buf:?}");
                (rank, buf, [after1, after2, after3])
            })
        })
        .collect();

    let mut results: Vec<(usize, Vec<f32>, [String; 3])> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by_key(|(r, _, _)| *r);

    for phase in 0..3 {
        println!("--- phase {} ---", phase + 1);
        for (_, _, log) in &results {
            println!("  {}", log[phase]);
        }
    }

    println!("\nverification:");
    for (rank, buf, _) in &results {
        assert_eq!(buf, &want, "GPU{rank} result mismatch");
        println!("  GPU{rank}: {buf:?}  ✓");
    }
    println!(
        "\nper-rank p2p steps: torus 2x2 = {} vs flat ring over 4 = {}",
        torus.p2p_steps(4),
        2 * (4 - 1)
    );
    println!("OK: all ranks hold the global sum (paper Figure 2 reproduced)");
}
