//! Quickstart: the smallest end-to-end run.
//!
//! Trains the `tiny` net on 4 workers arranged in the paper's 2×2
//! 2D-torus (Figure 2's example grid) for 30 steps, with label smoothing,
//! FP16 gradient exchange and LARS, on the pure-Rust reference backend —
//! every layer of the stack, from a clean checkout, in seconds:
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use flashsgd::prelude::*;

fn main() -> Result<()> {
    let config = TrainConfig::quickstart();
    println!(
        "quickstart: {} workers, collective {}, {} steps",
        config.batch.max_workers(),
        config.collective,
        config.max_steps
    );

    let trainer = Trainer::new(config)?;
    let report = trainer.run()?;

    println!("{}", report.format());
    println!(
        "compute pool: {} lanes, peak concurrency {} (steps/s: {:.1})",
        report.lanes,
        report.max_lane_concurrency,
        report.summary.steps as f64 / report.summary.wall_secs.max(1e-9)
    );
    println!("\nloss curve (EMA):");
    for (step, loss) in report.metrics.loss_curve(5) {
        let bar = "#".repeat((loss * 12.0).min(60.0) as usize);
        println!("  step {step:>4}  {loss:>7.4}  {bar}");
    }

    let s = &report.summary;
    assert!(
        s.last_loss < s.first_loss,
        "training must reduce the loss: {:.3} -> {:.3}",
        s.first_loss,
        s.last_loss
    );
    println!("\nOK: loss decreased {:.3} -> {:.3}", s.first_loss, s.last_loss);
    Ok(())
}
