//! End-to-end driver (DESIGN.md validation run): the paper's full recipe at
//! reduced scale, a few hundred steps, loss curve logged for EXPERIMENTS.md.
//!
//! This is the Exp. 2 *twin*: 8 workers in a torus, per-worker batch 16→32
//! at the scaled phase boundary (batch-size control triggers the grad-
//! executable swap), label smoothing 0.1, config-B LR/momentum schedule
//! (linearly rescaled from the 54K-batch values), LARS in the Pallas
//! kernel, FP16 gradient wire, FP32 BN-stat wire.
//!
//!     cargo run --release --example train_e2e
//!
//! Flags: --arch tiny  --ranks N  --epochs E  --csv PATH

use anyhow::Result;
use flashsgd::prelude::*;

fn flag(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<()> {
    let arch = flag("--arch").unwrap_or_else(|| "tiny".to_string());
    let ranks: usize = flag("--ranks").map_or(8, |s| s.parse().unwrap());
    let epochs: u32 = flag("--epochs").map_or(6, |s| s.parse().unwrap());

    let paper = paper_run("exp2").expect("exp2 preset");
    let mut config = TrainConfig::twin_of(&paper, ranks, &arch, epochs);
    config.train_size = 8192;
    config.eval_every = 32; // one validation pass every 32 optimizer steps
    config.eval_batches = 8;

    println!("=== train_e2e: paper Exp. 2 at reduced scale ===");
    println!(
        "arch={arch} ranks={ranks} epochs={epochs} collective={} ls={} wire={}",
        config.collective, config.label_smoothing, config.grad_wire
    );
    for p in config.batch.phases() {
        println!(
            "  phase from epoch {:>2}: batch {}/worker x {} workers = {} global",
            p.from_epoch,
            p.per_worker,
            p.workers,
            p.total_batch()
        );
    }

    let trainer = Trainer::new(config)?;
    let report = trainer.run()?;

    println!("\n{}", report.format());
    let curve: Vec<(f64, f64)> = report
        .metrics
        .loss_curve(1)
        .into_iter()
        .map(|(s, l)| (s as f64, l))
        .collect();
    println!(
        "\n{}",
        flashsgd::util::plot::line_plot(&curve, 64, 12, "training loss (EMA)")
    );
    println!("loss curve (EMA over steps):");
    for (step, loss) in report.metrics.loss_curve(10) {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!("\nevals:");
    for e in &report.metrics.evals {
        println!(
            "  step {:>5}  val loss {:.4}  top-1 {:.1}%",
            e.step,
            e.val_loss,
            e.accuracy * 100.0
        );
    }

    if let Some(path) = flag("--csv") {
        std::fs::write(&path, report.metrics.to_csv())?;
        println!("wrote {path}");
    }

    // End-to-end assertions: all layers composed and training worked.
    let s = &report.summary;
    assert!(s.steps > 50, "expected a real run, got {} steps", s.steps);
    assert!(
        s.last_loss < s.first_loss * 0.9,
        "loss must drop >10%: {:.3} -> {:.3}",
        s.first_loss,
        s.last_loss
    );
    let acc = report.final_eval.as_ref().map(|e| e.accuracy).unwrap_or(0.0);
    assert!(
        acc > 0.2,
        "top-1 must beat 10-class chance by 2x, got {:.1}%",
        acc * 100.0
    );
    println!(
        "\nOK: {} steps, loss {:.3} -> {:.3}, top-1 {:.1}%",
        s.steps,
        s.first_loss,
        s.last_loss,
        acc * 100.0
    );
    Ok(())
}
