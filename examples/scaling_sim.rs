//! Scaling study (paper Tables 2 & 6): project throughput and GPU scaling
//! efficiency from 4 to 4096 GPUs with the ABCI cluster model, comparing
//! the 2D-torus against the flat-ring and hierarchical baselines, and
//! validate the closed-form costs against the discrete-event simulator.
//!
//!     cargo run --release --example scaling_sim

use flashsgd::cluster::best_grid;
use flashsgd::repro;
use flashsgd::simnet::{
    simulate_collective, Algo, ClusterModel, RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16,
};

fn main() {
    let m = ClusterModel::abci_v100();
    let bytes = RESNET50_GRAD_BYTES_FP16;

    println!("{}", repro::table6());
    println!("{}", repro::table2());

    println!("collective comparison (25.5M-param ResNet-50, FP16 grads):");
    println!(
        "{:>6}  {:>14} {:>14} {:>14}  {:>9}",
        "#GPUs", "torus (ms)", "hier (ms)", "ring (ms)", "torus win"
    );
    for n in [16usize, 64, 256, 1024, 2048, 4096] {
        let (x, y) = best_grid(n);
        let torus = m.collective_cost(Algo::Torus { x, y }, n, bytes).total_secs();
        let hier = m
            .collective_cost(Algo::Hierarchical { group: 4 }, n, bytes)
            .total_secs();
        let ring = m.collective_cost(Algo::Ring, n, bytes).total_secs();
        println!(
            "{:>6}  {:>14.3} {:>14.3} {:>14.3}  {:>8.2}x",
            n,
            torus * 1e3,
            hier * 1e3,
            ring * 1e3,
            ring / torus
        );
    }

    println!("\nclosed-form vs discrete-event validation (torus):");
    println!("{:>10}  {:>14} {:>14} {:>8}", "grid", "analytic (ms)", "event (ms)", "ratio");
    for (x, y) in [(2usize, 2usize), (8, 8), (32, 32), (64, 32), (64, 64)] {
        let n = x * y;
        let analytic = m.collective_cost(Algo::Torus { x, y }, n, bytes).total_secs();
        let event = simulate_collective(&m, Algo::Torus { x, y }, n, bytes);
        println!(
            "{:>7}x{:<3} {:>13.3} {:>14.3} {:>8.3}",
            x,
            y,
            analytic * 1e3,
            event * 1e3,
            event / analytic
        );
    }

    println!("\nstep-time breakdown at the paper's scales (B=32/worker):");
    for n in [4usize, 1024, 2048, 3456, 4096] {
        let (x, y) = best_grid(n);
        let st = m.step_time(
            Algo::Torus { x, y },
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        );
        println!(
            "  {:>5} GPUs ({:>2}x{:<2}): {:>7.2} ms  = compute {:>6.2} + grads {:>6.2} + bn {:>5.2}",
            n,
            x,
            y,
            st.total_secs() * 1e3,
            st.compute_secs * 1e3,
            st.grad_comm_secs * 1e3,
            st.bn_comm_secs * 1e3
        );
    }
}
