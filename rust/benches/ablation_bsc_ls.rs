//! Ablation A1 (paper §3.3): label smoothing × batch-size control.
//!
//! The paper's findings, reproduced at reduced scale on the synthetic set:
//!   * BSC alone lets the max batch grow without accuracy loss (Exp. 4),
//!   * LS alone stabilises a large *initial* batch (Exp. 2),
//!   * both together at the largest batch cost ~0.7% (Exp. 3).
//!
//! Four twins over the same step budget: {LS on/off} × {BSC on/off}.
//!
//!     cargo bench --bench ablation_bsc_ls

use flashsgd::config::TrainConfig;
use flashsgd::coordinator::Trainer;
use flashsgd::sched::{BatchSchedule, LrSchedule, Phase};

fn run_case(name: &str, ls: f32, bsc: bool, ranks: usize) -> Option<(f64, f64)> {
    let epochs = 4u32;
    let batch = if bsc {
        BatchSchedule::new(
            vec![
                Phase { from_epoch: 0, per_worker: 8, workers: ranks },
                Phase { from_epoch: 2, per_worker: 16, workers: ranks },
            ],
            epochs,
        )
    } else {
        BatchSchedule::constant(8, ranks, epochs)
    };
    let config = TrainConfig {
        name: name.to_string(),
        arch: "tiny".into(),
        collective: "torus".into(),
        grad_wire: "fp16".into(),
        label_smoothing: ls,
        lr: LrSchedule::ConfigB {
            warmup_epochs: 0.5,
            warmup_start: 0.05,
            base_low: 2.0,
            base_high: 3.0,
            switch_epoch: 2.0,
            total_epochs: epochs as f64,
        },
        batch,
        weight_decay: 5e-5,
        seed: 42,
        max_steps: 0,
        eval_every: 0,
        eval_batches: 8,
        train_size: 4096,
        compute_lanes: 0,
        bucket_bytes: 8192,
        fault: flashsgd::config::FaultConfig::default(),
        transport: flashsgd::config::TransportConfig::default(),
        checkpoint: flashsgd::config::CheckpointConfig::default(),
    };
    let trainer = Trainer::new(config).ok()?;
    let report = trainer.run().ok()?;
    let acc = report.final_eval.as_ref().map(|e| e.accuracy).unwrap_or(0.0);
    Some((acc, report.summary.last_loss))
}

fn main() {
    let ranks = 8;
    println!("=== ablation: label smoothing x batch-size control (tiny twin, {ranks} ranks) ===\n");
    println!(
        "{:<28} {:>8} {:>8} {:>10} {:>12}",
        "case", "LS", "BSC", "top-1", "final loss"
    );
    let cases = [
        ("baseline", 0.0f32, false),
        ("label smoothing only", 0.1, false),
        ("batch-size control only", 0.0, true),
        ("both (exp3-style)", 0.1, true),
    ];
    let mut results = Vec::new();
    for (name, ls, bsc) in cases {
        match run_case(name, ls, bsc, ranks) {
            Some((acc, loss)) => {
                println!(
                    "{:<28} {:>8} {:>8} {:>9.1}% {:>12.3}",
                    name,
                    if ls > 0.0 { "0.1" } else { "off" },
                    if bsc { "16->32" } else { "off" },
                    acc * 100.0,
                    loss
                );
                results.push((name, acc));
            }
            None => eprintln!("{name}: skipped (trainer failed)"),
        }
    }
    if results.len() == 4 {
        let base = results[0].1;
        println!("\nrelative to baseline:");
        for (name, acc) in &results[1..] {
            println!("  {name:<28} {:+.1}pp", (acc - base) * 100.0);
        }
        println!("\n(paper shape: each stabiliser alone holds accuracy at its target");
        println!(" batch; combining both at the largest batch costs ~0.7pp — Exp. 3)");
    }
}
