//! Table 2: GPU scaling efficiency at 1024 GPUs vs the literature, plus a
//! sensitivity sweep over the link-model constants (how robust is the
//! "84.75%" shape to the calibration?).
//!
//!     cargo bench --bench table2_efficiency

use flashsgd::cluster::best_grid;
use flashsgd::repro;
use flashsgd::simnet::{
    Algo, ClusterModel, ComputeModel, LinkModel, RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16,
};

fn torus_at(n: usize) -> Algo {
    let (x, y) = best_grid(n);
    Algo::Torus { x, y }
}

fn eff_at_1024(m: &ClusterModel) -> f64 {
    100.0
        * m.scaling_efficiency(
            torus_at,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        )
}

fn main() {
    println!("=== table2_efficiency ===\n");
    print!("{}", repro::table2());

    let base = ClusterModel::abci_v100();
    println!("\nsensitivity of the modelled 1024-GPU efficiency:");
    println!("{:<44} {:>10}", "variant", "efficiency");
    println!("{:<44} {:>9.2}%", "calibrated ABCI model", eff_at_1024(&base));

    // IB latency x2 / x0.5
    for (label, alpha) in [("IB latency x2 (10us)", 10.0e-6), ("IB latency /2 (2.5us)", 2.5e-6)] {
        let mut m = base.clone();
        m.lm.alpha_inter = alpha;
        println!("{:<44} {:>9.2}%", label, eff_at_1024(&m));
    }
    // IB bandwidth x2 / x0.5
    for (label, scale) in [("IB bandwidth x2", 2.0), ("IB bandwidth /2", 0.5)] {
        let mut m = base.clone();
        m.lm.beta_inter_flow /= scale;
        m.lm.node_inter_bw *= scale;
        println!("{:<44} {:>9.2}%", label, eff_at_1024(&m));
    }
    // faster / slower GPU (efficiency falls as compute shrinks — the
    // paper's V100-vs-P40 point in §3.3)
    for (label, scale) in [("GPU 2x faster (comm relatively heavier)", 2.0),
                           ("GPU 2x slower (comm hides)", 0.5)] {
        let mut m = base.clone();
        m.cm = ComputeModel {
            peak_images_per_sec: base.cm.peak_images_per_sec * scale,
            b_half: base.cm.b_half,
        };
        println!("{:<44} {:>9.2}%", label, eff_at_1024(&m));
    }
    // no congestion model
    {
        let mut m = base.clone();
        m.lm = LinkModel {
            congestion_slope: 0.0,
            ..base.lm.clone()
        };
        println!("{:<44} {:>9.2}%", "no fabric congestion term", eff_at_1024(&m));
    }

    println!("\nalgorithm ablation at 1024 GPUs (B=32/worker):");
    for (label, algo) in [
        ("2D-torus 32x32 (paper)", Algo::Torus { x: 32, y: 32 }),
        ("hierarchical g=4 (Jia et al.)", Algo::Hierarchical { group: 4 }),
        ("flat ring (Baidu)", Algo::Ring),
    ] {
        let eff = 100.0
            * base.scaling_efficiency(
                |n| if n == 4 { torus_at(4) } else { algo },
                1024,
                32,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
            );
        println!("  {label:<36} {eff:>6.2}%");
    }
}
