//! Table 6 (+ Table 4): 2D-torus throughput and GPU scaling efficiency at
//! 4→4096 GPUs, modelled on the ABCI cluster model and cross-validated
//! against the discrete-event simulator; baselines included.
//!
//!     cargo bench --bench table6_scaling

use flashsgd::cluster::{best_grid, TABLE4_GRIDS};
use flashsgd::repro;
use flashsgd::simnet::{
    simulate_collective, Algo, ClusterModel, RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16,
};
use flashsgd::util::timer::bench_adaptive;

fn main() {
    println!("=== table6_scaling ===\n");
    print!("{}", repro::table4());
    println!();
    print!("{}", repro::table6());

    let m = ClusterModel::abci_v100();
    let paper: &[(usize, f64)] = &[
        (1024, 84.75),
        (2048, 83.10),
        (3456, 74.08),
        (4096, 73.44),
    ];
    println!("\nmodel vs paper efficiency deltas:");
    let mut max_delta: f64 = 0.0;
    for &(n, paper_eff) in paper {
        let eff = 100.0
            * m.scaling_efficiency(
                |k| {
                    let (x, y) = best_grid(k);
                    Algo::Torus { x, y }
                },
                n,
                32,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
            );
        let d = eff - paper_eff;
        max_delta = max_delta.max(d.abs());
        println!("  {n:>5} GPUs: model {eff:>6.2}%  paper {paper_eff:>6.2}%  delta {d:>+5.2}pp");
    }
    println!("  max |delta| = {max_delta:.2} percentage points");

    println!("\nbaseline comparison at each Table 4 scale (grad all-reduce ms):");
    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>12}",
        "#GPUs", "grid", "torus", "hierarchical", "ring"
    );
    for &(n, v, h) in TABLE4_GRIDS {
        let t = m
            .collective_cost(Algo::Torus { x: h, y: v }, n, RESNET50_GRAD_BYTES_FP16)
            .total_secs();
        let hi = m
            .collective_cost(Algo::Hierarchical { group: 4 }, n, RESNET50_GRAD_BYTES_FP16)
            .total_secs();
        let r = m
            .collective_cost(Algo::Ring, n, RESNET50_GRAD_BYTES_FP16)
            .total_secs();
        println!(
            "{:>6} {:>7}x{:<3} {:>11.2}ms {:>11.2}ms {:>11.2}ms",
            n, h, v, t * 1e3, hi * 1e3, r * 1e3
        );
    }

    println!("\ndiscrete-event cross-validation (torus, grad bytes):");
    for &(n, v, h) in TABLE4_GRIDS {
        let analytic = m
            .collective_cost(Algo::Torus { x: h, y: v }, n, RESNET50_GRAD_BYTES_FP16)
            .total_secs();
        let event = simulate_collective(&m, Algo::Torus { x: h, y: v }, n, RESNET50_GRAD_BYTES_FP16);
        println!(
            "  {n:>5} GPUs: analytic {:.3} ms, event {:.3} ms (ratio {:.3})",
            analytic * 1e3,
            event * 1e3,
            event / analytic
        );
    }

    // Model evaluation cost itself (it is the inner loop of every sweep).
    let r = bench_adaptive("model: full table-6 sweep", 200.0, || {
        for &(n, _) in paper {
            let (x, y) = best_grid(n);
            let _ = m.throughput(
                Algo::Torus { x, y },
                n,
                32,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
            );
        }
    });
    println!("\n{}", r.line());
}
