//! Wall-clock steps/s: monolithic vs bucketed gradient reduction.
//!
//! Runs the 4-worker tiny-arch quickstart twice — `bucket_bytes = 0`
//! (single bucket: the serial grad→reduce→apply schedule) and the default
//! bucketed pipeline (reduction overlapped with backprop) — and reports
//! steps/s, peak compute-lane concurrency and the exposed-comm fraction.
//! Emits `BENCH_pipeline.json` next to the working directory so the repo
//! accumulates a perf trajectory.
//!
//!     cargo bench --bench step_pipeline
//!
//! CI only builds this target (`cargo bench --no-run`); record numbers
//! from a toolchain'd checkout and paste them into the PR description —
//! see README "Overlapped bucketed reduction".

use std::collections::BTreeMap;

use flashsgd::config::TrainConfig;
use flashsgd::coordinator::{TrainReport, Trainer};
use flashsgd::util::json::Json;

struct Case {
    name: &'static str,
    bucket_bytes: usize,
    steps_per_sec: f64,
    exposed_comm_fraction: f64,
    hidden_comm_ms: f64,
    max_lane_concurrency: usize,
    n_steps: usize,
}

fn run_case(name: &'static str, bucket_bytes: usize, steps: usize) -> Case {
    let mut config = TrainConfig::quickstart();
    config.name = format!("bench-{name}");
    config.max_steps = steps;
    config.bucket_bytes = bucket_bytes;
    let report: TrainReport = Trainer::new(config)
        .expect("quickstart config must construct")
        .run()
        .expect("bench run must complete");
    let s = &report.summary;
    Case {
        name,
        bucket_bytes,
        steps_per_sec: s.steps as f64 / s.wall_secs.max(1e-9),
        exposed_comm_fraction: s.comm_fraction,
        hidden_comm_ms: s.mean_comm_hidden * 1e3,
        max_lane_concurrency: report.max_lane_concurrency,
        n_steps: s.steps,
    }
}

fn case_json(c: &Case) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(c.name.to_string()));
    m.insert("bucket_bytes".to_string(), Json::Num(c.bucket_bytes as f64));
    m.insert("steps".to_string(), Json::Num(c.n_steps as f64));
    m.insert("steps_per_sec".to_string(), Json::Num(c.steps_per_sec));
    m.insert(
        "exposed_comm_fraction".to_string(),
        Json::Num(c.exposed_comm_fraction),
    );
    m.insert("hidden_comm_ms".to_string(), Json::Num(c.hidden_comm_ms));
    m.insert(
        "max_lane_concurrency".to_string(),
        Json::Num(c.max_lane_concurrency as f64),
    );
    Json::Obj(m)
}

fn main() {
    let steps = 60usize;
    println!("=== step pipeline: monolithic vs bucketed reduction (tiny, 2x2 torus) ===\n");
    // warmup to stabilise thread-pool and allocator state
    let _ = run_case("warmup", 0, 10);

    let cases = vec![
        run_case("monolithic", 0, steps),
        run_case("bucketed-default", TrainConfig::quickstart().bucket_bytes, steps),
        run_case("bucketed-fine", 2048, steps),
    ];

    println!(
        "{:<20} {:>12} {:>10} {:>14} {:>14} {:>10}",
        "case", "bucket_bytes", "steps/s", "exposed-comm%", "hidden ms", "max-conc"
    );
    for c in &cases {
        println!(
            "{:<20} {:>12} {:>10.1} {:>13.1}% {:>14.3} {:>10}",
            c.name,
            c.bucket_bytes,
            c.steps_per_sec,
            c.exposed_comm_fraction * 100.0,
            c.hidden_comm_ms,
            c.max_lane_concurrency
        );
    }

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("step_pipeline".to_string()));
    top.insert("recorded".to_string(), Json::Bool(true));
    top.insert(
        "workers".to_string(),
        Json::Num(TrainConfig::quickstart().batch.max_workers() as f64),
    );
    top.insert(
        "cases".to_string(),
        Json::Arr(cases.iter().map(case_json).collect()),
    );
    let json = Json::Obj(top);
    let path = "BENCH_pipeline.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
