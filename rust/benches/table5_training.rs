//! Table 5: validation accuracy and training time — reduced-scale twins.
//!
//! Trains each paper run's twin (same stabilisers: batch-size control
//! phases, label smoothing, LARS, config-A/B schedules; worker counts
//! scaled to a thread mesh, synthetic 10-class dataset) and reports final
//! accuracy next to the paper's, plus the simnet-modelled full-scale time.
//!
//! Runs on the pure-Rust reference backend — no artifacts needed.
//!
//!     cargo bench --bench table5_training
//!
//! Env: FLASHSGD_T5_EPOCHS (default 4), FLASHSGD_T5_RANKS (default 8),
//!      FLASHSGD_T5_ARCH (default tiny).

use flashsgd::config::{paper_runs, TrainConfig};
use flashsgd::coordinator::Trainer;
use flashsgd::repro::simulated_training_secs;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let epochs = env_usize("FLASHSGD_T5_EPOCHS", 4) as u32;
    let ranks = env_usize("FLASHSGD_T5_RANKS", 8);
    let arch = std::env::var("FLASHSGD_T5_ARCH").unwrap_or_else(|_| "tiny".to_string());

    println!("=== table5_training: reduced-scale twins ({arch}, {ranks} ranks, {epochs} epochs) ===\n");
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12} {:>14} {:>12}",
        "run", "paper acc", "twin top-1", "twin loss", "paper time", "modelled time", "twin wall"
    );

    let mut rows = Vec::new();
    for paper in paper_runs() {
        let mut config = TrainConfig::twin_of(&paper, ranks, &arch, epochs);
        config.train_size = 4096;
        config.eval_batches = 8;
        let trainer = match Trainer::new(config) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {}: {e:#}", paper.name);
                continue;
            }
        };
        let report = match trainer.run() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{} failed: {e:#}", paper.name);
                continue;
            }
        };
        let acc = report.final_eval.as_ref().map(|e| e.accuracy).unwrap_or(0.0);
        let modelled = simulated_training_secs(paper.name);
        println!(
            "{:<10} {:>9.2}% {:>11.1}% {:>12.3} {:>11.0}s {:>13.0}s {:>11.1}s",
            paper.name,
            paper.paper_accuracy,
            acc * 100.0,
            report.summary.last_loss,
            paper.paper_secs,
            modelled,
            report.wall_secs
        );
        rows.push((paper.name, acc, report.summary.last_loss));
    }

    println!("\nshape checks (paper §3.3 claims at reduced scale):");
    let get = |name: &str| rows.iter().find(|(n, _, _)| *n == name);
    if let (Some(r), Some(e2)) = (get("reference"), get("exp2")) {
        println!(
            "  exp2 (LS, 54K-twin) within 10pp of reference: {:.1}% vs {:.1}%  [{}]",
            e2.1 * 100.0,
            r.1 * 100.0,
            if (e2.1 - r.1).abs() < 0.10 { "ok" } else { "DIVERGES" }
        );
    }
    if let (Some(e2), Some(e3)) = (get("exp2"), get("exp3")) {
        println!(
            "  exp3 (LS+BSC, larger max batch) <= exp2 accuracy: {:.1}% vs {:.1}%  [{}]",
            e3.1 * 100.0,
            e2.1 * 100.0,
            if e3.1 <= e2.1 + 0.05 { "ok" } else { "DIVERGES" }
        );
    }
    println!("\n(each twin trains all stabilisers through the real stack; absolute");
    println!(" accuracies are on the synthetic 10-class set, not ImageNet)");
}
