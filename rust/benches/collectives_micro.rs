//! Collective micro-benchmarks (paper Figure 2 / §2.2; ablation A2).
//!
//! Runs the *functional* collectives — real data through real thread
//! meshes — across algorithms, rank counts and message sizes. Reports
//! wall time, effective algorithm bandwidth, and the measured per-rank
//! byte volume (which must match each scheme's analytic formula).
//! Emits `BENCH_collectives.json` into the working directory so the repo
//! accumulates a perf trajectory (see `tools/record_baselines.sh`).
//!
//!     cargo bench --bench collectives_micro

use std::collections::BTreeMap;
use std::sync::Arc;
use std::thread;

use flashsgd::cluster::best_grid;
use flashsgd::collectives::{
    Collective, HierarchicalAllReduce, Mesh, RingAllReduce, TcpMesh, TorusAllReduce, Transport,
    Wire,
};
use flashsgd::util::json::Json;
use flashsgd::util::timer::{bench_adaptive, fmt_ns};

/// One recorded measurement for `BENCH_collectives.json`.
fn row(
    sweep: &str,
    algo: &str,
    ranks: usize,
    elems: usize,
    mean_ns: f64,
    extra: &[(&str, f64)],
) -> Json {
    let mut m = BTreeMap::new();
    m.insert("sweep".to_string(), Json::Str(sweep.to_string()));
    m.insert("algo".to_string(), Json::Str(algo.to_string()));
    m.insert("ranks".to_string(), Json::Num(ranks as f64));
    m.insert("elems".to_string(), Json::Num(elems as f64));
    m.insert("mean_ns".to_string(), Json::Num(mean_ns));
    for (k, v) in extra {
        m.insert((*k).to_string(), Json::Num(*v));
    }
    Json::Obj(m)
}

/// One timed all-reduce over a pre-built set of endpoints. The clock
/// starts *after* the mesh is up, so memory and TCP rows time the same
/// thing: the reduction itself, not socket setup.
fn run_once_on<T: Transport + Send + 'static>(
    eps: Vec<T>,
    coll: &Arc<dyn Collective>,
    elems: usize,
    wire: Wire,
) -> (f64, u64) {
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let coll = coll.clone();
            thread::spawn(move || {
                let mut buf: Vec<f32> =
                    (0..elems).map(|i| (ep.rank() + i) as f32 * 1e-3).collect();
                coll.all_reduce(&mut ep, &mut buf, wire, 0).unwrap();
                ep.counters().snapshot().0
            })
        })
        .collect();
    let mut sent = 0;
    for h in handles {
        sent = h.join().unwrap();
    }
    (t0.elapsed().as_secs_f64(), sent)
}

/// One timed all-reduce across a fresh in-memory mesh of `n` ranks.
fn run_once(coll: &Arc<dyn Collective>, n: usize, elems: usize, wire: Wire) -> (f64, u64) {
    run_once_on(Mesh::new(n), coll, elems, wire)
}

/// Same, across a fresh loopback-TCP mesh — real sockets, framed wire.
fn run_once_tcp(coll: &Arc<dyn Collective>, n: usize, elems: usize, wire: Wire) -> (f64, u64) {
    run_once_on(
        TcpMesh::loopback(n).expect("loopback mesh"),
        coll,
        elems,
        wire,
    )
}

fn main() {
    println!("=== collectives_micro: functional all-reduce over thread mesh ===\n");
    let mut rows: Vec<Json> = Vec::new();

    // Figure 2 sanity row: the paper's 2x2 worked example.
    {
        let coll: Arc<dyn Collective> = Arc::new(TorusAllReduce::new(2, 2));
        let (secs, bytes) = run_once(&coll, 4, 1 << 16, Wire::F32);
        println!(
            "figure-2 grid 2x2, 64K floats, fp32: {:.3} ms, {} bytes on the wire\n",
            secs * 1e3,
            bytes
        );
    }

    // Algorithm x size sweep at a fixed rank count.
    let n = 16usize;
    let (gx, gy) = best_grid(n);
    let algos: Vec<(&str, Arc<dyn Collective>)> = vec![
        ("ring", Arc::new(RingAllReduce)),
        ("hierarchical:4", Arc::new(HierarchicalAllReduce::new(4))),
        ("torus", Arc::new(TorusAllReduce::new(gx, gy))),
    ];
    println!("{n} ranks, fp16 wire (paper gradient path):");
    println!(
        "{:<16} {:>12} {:>14} {:>14} {:>16}",
        "algo", "elems", "time", "alg-bw GB/s", "bytes/rank"
    );
    for (name, coll) in &algos {
        for elems in [1usize << 10, 1 << 14, 1 << 18, 1 << 22] {
            let r = bench_adaptive(&format!("{name}/{elems}"), 300.0, || {
                let _ = run_once(coll, n, elems, Wire::F16);
            });
            let (_, bytes) = run_once(coll, n, elems, Wire::F16);
            // algorithm bandwidth: 2*(n-1)/n * data / time (ring convention)
            let payload = 4.0 * elems as f64;
            let algbw = 2.0 * (n as f64 - 1.0) / n as f64 * payload / r.mean_secs();
            println!(
                "{:<16} {:>12} {:>14} {:>14.2} {:>16}",
                name,
                elems,
                fmt_ns(r.mean_ns),
                algbw / 1e9,
                bytes / n as u64
            );
            rows.push(row(
                "algo_x_size",
                name,
                n,
                elems,
                r.mean_ns,
                &[
                    ("algbw_gbps", algbw / 1e9),
                    ("bytes_per_rank", (bytes / n as u64) as f64),
                ],
            ));
        }
    }

    // Rank scaling at ResNet-50-like message size (25.5M f32 ~ 102 MB).
    // Scaled to 1.6M floats to keep the bench under a minute.
    println!("\nrank scaling, 1.6M floats, fp16 wire:");
    println!(
        "{:<16} {:>7} {:>14} {:>12}",
        "algo", "ranks", "time", "p2p steps"
    );
    for n in [4usize, 8, 16, 32] {
        let (x, y) = best_grid(n);
        let cases: Vec<(&str, Arc<dyn Collective>)> = vec![
            ("ring", Arc::new(RingAllReduce)),
            ("torus", Arc::new(TorusAllReduce::new(x, y))),
        ];
        for (name, coll) in cases {
            let steps = coll.p2p_steps(n);
            let r = bench_adaptive(&format!("{name}/{n}"), 400.0, || {
                let _ = run_once(&coll, n, 1 << 20 | 1 << 19, Wire::F16);
            });
            println!("{:<16} {:>7} {:>14} {:>12}", name, n, fmt_ns(r.mean_ns), steps);
            rows.push(row(
                "rank_scaling",
                name,
                n,
                1 << 20 | 1 << 19,
                r.mean_ns,
                &[("p2p_steps", steps as f64)],
            ));
        }
    }

    // Transport comparison: the identical schedule over the in-memory
    // mesh and over loopback TCP (framed wire, reader threads). The
    // delta is the full codec + kernel-socket cost per reduction; byte
    // counters must agree exactly — both bill logical payload only.
    println!("\ntransport sweep: memory vs loopback TCP, 8 ranks, fp16 wire:");
    println!(
        "{:<16} {:>10} {:>14} {:>14} {:>9}",
        "algo", "elems", "memory", "tcp", "tcp/mem"
    );
    {
        let n = 8usize;
        let (x, y) = best_grid(n);
        let pair: Vec<(&str, Arc<dyn Collective>)> = vec![
            ("ring", Arc::new(RingAllReduce)),
            ("torus", Arc::new(TorusAllReduce::new(x, y))),
        ];
        for (name, coll) in &pair {
            for elems in [1usize << 12, 1 << 16, 1 << 20] {
                let rm = bench_adaptive(&format!("{name}/{elems}/mem"), 250.0, || {
                    let _ = run_once(coll, n, elems, Wire::F16);
                });
                let rt = bench_adaptive(&format!("{name}/{elems}/tcp"), 250.0, || {
                    let _ = run_once_tcp(coll, n, elems, Wire::F16);
                });
                let (_, mem_bytes) = run_once(coll, n, elems, Wire::F16);
                let (_, tcp_bytes) = run_once_tcp(coll, n, elems, Wire::F16);
                assert_eq!(
                    mem_bytes, tcp_bytes,
                    "{name}: transports disagree on wire bytes"
                );
                println!(
                    "{:<16} {:>10} {:>14} {:>14} {:>8.2}x",
                    name,
                    elems,
                    fmt_ns(rm.mean_ns),
                    fmt_ns(rt.mean_ns),
                    rt.mean_secs() / rm.mean_secs()
                );
                rows.push(row("transport_mem", name, n, elems, rm.mean_ns, &[]));
                rows.push(row(
                    "transport_tcp",
                    name,
                    n,
                    elems,
                    rt.mean_ns,
                    &[("tcp_over_mem", rt.mean_secs() / rm.mean_secs())],
                ));
            }
        }
    }

    let mut top = BTreeMap::new();
    top.insert(
        "bench".to_string(),
        Json::Str("collectives_micro".to_string()),
    );
    top.insert("recorded".to_string(), Json::Bool(true));
    top.insert("rows".to_string(), Json::Arr(rows));
    let path = "BENCH_collectives.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    println!("\n(thread-mesh timings measure the functional path; cluster-scale");
    println!(" projections are in `cargo bench --bench table6_scaling`)");
}
