//! Hierarchical ring all-reduce — the paper's second baseline (its ref. [6],
//! Jia et al., "tencent" scheme).
//!
//! Ranks are split into groups of `group_size` (one group ≈ one node, e.g.
//! 4 GPUs on NVLink). Three phases:
//!
//!   1. intra-group ring reduce-scatter (each member ends owning `1/g`),
//!   2. inter-group ring all-reduce among same-position members across all
//!      groups (`N/g` ranks, chunk size `n/g`),
//!   3. intra-group ring all-gather.
//!
//! Same per-rank step count as a 2D-torus with `x = g, y = N/g`, but the
//! inter-group phase moves `n/g` elements per step versus the torus's
//! `n/(x·y)` — the X-fold difference the paper calls out in §2.2.

use anyhow::{bail, Result};

use super::primitives::{
    chunk_offsets, ring_all_gather, ring_all_reduce, ring_reduce_scatter, Wire,
};
use super::transport::Transport;
use super::Collective;

/// Hierarchical (grouped) ring all-reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierarchicalAllReduce {
    /// Ranks per group (intra-node ring length; 4 on an ABCI node).
    pub group_size: usize,
}

impl HierarchicalAllReduce {
    pub fn new(group_size: usize) -> Self {
        assert!(group_size > 0);
        Self { group_size }
    }

    fn intra_group(&self, rank: usize) -> Vec<usize> {
        let g = self.group_size;
        let base = rank / g * g;
        (0..g).map(|i| base + i).collect()
    }

    fn inter_group(&self, rank: usize, n: usize) -> Vec<usize> {
        let g = self.group_size;
        let pos = rank % g;
        (0..n / g).map(|j| j * g + pos).collect()
    }
}

impl Collective for HierarchicalAllReduce {
    fn name(&self) -> String {
        format!("hierarchical(g={})", self.group_size)
    }

    fn all_reduce(
        &self,
        ep: &mut dyn Transport,
        buf: &mut [f32],
        wire: Wire,
        tag_base: u64,
    ) -> Result<()> {
        let n = ep.world_size();
        let g = self.group_size;
        if n % g != 0 {
            bail!("hierarchical: world size {n} not divisible by group size {g}");
        }
        let rank = ep.rank();
        let intra = self.intra_group(rank);
        let inter = self.inter_group(rank, n);
        let intra_pos = rank % g;
        let inter_pos = rank / g;

        let t_scatter = tag_base;
        let t_inter = tag_base + g as u64;
        let t_gather = t_inter + 2 * (n / g) as u64;

        // Phase 1: intra-group reduce-scatter.
        let owned = ring_reduce_scatter(ep, &intra, intra_pos, buf, wire, t_scatter)?;

        // Phase 2: inter-group all-reduce of the owned chunk (size n/g —
        // the full group-chunk, NOT further subdivided; this is the extra
        // data volume relative to the 2D-torus vertical phase).
        let offs = chunk_offsets(buf.len(), g);
        let chunk = &mut buf[offs[owned]..offs[owned + 1]];
        ring_all_reduce(ep, &inter, inter_pos, chunk, wire, t_inter)?;

        // Phase 3: intra-group all-gather.
        ring_all_gather(ep, &intra, intra_pos, buf, wire, t_gather)
    }

    fn p2p_steps(&self, n_ranks: usize) -> usize {
        let g = self.group_size;
        2 * (g - 1) + 2 * (n_ranks / g - 1)
    }

    fn tag_span(&self, n_ranks: usize) -> u64 {
        (self.group_size + 2 * (n_ranks / self.group_size) + 2 * self.group_size) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::test_support::{check_all_reduce_matches_sum, run_collective};

    #[test]
    fn matches_sequential_sum() {
        for (g, n) in [(2, 4), (2, 8), (4, 8), (3, 9), (1, 3), (4, 4)] {
            let h = HierarchicalAllReduce::new(g);
            check_all_reduce_matches_sum(&h, n, 95, Wire::F32, 1e-4);
        }
    }

    #[test]
    fn fp16_wire_agreement() {
        check_all_reduce_matches_sum(&HierarchicalAllReduce::new(2), 8, 64, Wire::F16, 5e-3);
    }

    #[test]
    fn rejects_indivisible_world() {
        let h = HierarchicalAllReduce::new(3);
        let mut eps = crate::collectives::transport::Mesh::new(4);
        let mut ep = eps.remove(0);
        let mut buf = vec![0.0f32; 8];
        assert!(h.all_reduce(&mut ep, &mut buf, Wire::F32, 0).is_err());
    }

    #[test]
    fn step_count_same_as_equivalent_torus_and_total_volume_optimal() {
        // g=4 over 1024 ranks vs torus 4x256: identical step count.
        let h = HierarchicalAllReduce::new(4);
        let t = crate::collectives::torus2d::TorusAllReduce::new(4, 256);
        assert_eq!(h.p2p_steps(1024), t.p2p_steps(1024));

        // Every bandwidth-optimal all-reduce moves 2n(N-1)/N per rank in
        // TOTAL; hierarchical and torus differ in WHERE the second phase's
        // bytes land (n/g vs n/X chunks on the inter-node links, paper
        // §2.2), not in the grand total. Verify both facts.
        let h2 = HierarchicalAllReduce::new(2);
        let t2 = crate::collectives::torus2d::TorusAllReduce::new(2, 4);
        let n = 8usize;
        let elems = 64usize;
        let (_, (h_sent, _, _)) = run_collective(&h2, n, elems, Wire::F32);
        let (_, (t_sent, _, _)) = run_collective(&t2, n, elems, Wire::F32);
        let optimal = (n * 2 * elems * (n - 1) / n * 4) as u64;
        assert_eq!(h_sent, optimal, "hierarchical total volume");
        assert_eq!(t_sent, optimal, "torus total volume");
        // phase-2 volume claim (paper §2.2, the X/g factor) at N=1024,
        // comparing the paper's square 32x32 torus to hierarchical g=4
        // (per-rank, in units of the full message n):
        let n_total = 1024.0f64;
        let h_phase2 = 2.0 * (n_total / 4.0 - 1.0) / n_total; // ≈ 0.498 n
        let t_phase2 = 2.0 * (32.0 - 1.0) / n_total; //          ≈ 0.061 n
        assert!(
            h_phase2 / t_phase2 > 8.0,
            "phase-2 ratio {:.2} (expect ≈ X/g · step correction ≈ 8.2)",
            h_phase2 / t_phase2
        );
    }

    #[test]
    fn group_indexing() {
        let h = HierarchicalAllReduce::new(4);
        assert_eq!(h.intra_group(5), vec![4, 5, 6, 7]);
        assert_eq!(h.inter_group(5, 12), vec![1, 5, 9]);
    }
}
