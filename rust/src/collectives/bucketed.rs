//! Bucketed gradient reduction: the comm side of the backward-overlapped
//! pipeline (paper §2.2 / §3; Fujitsu's follow-up 1903.12650 calls the
//! same trick "gradient packing + overlap").
//!
//! The flat gradient is split into **tensor-aligned buckets** built in
//! reverse parameter order — the order the backward pass finalises
//! gradients — so bucket *k* can all-reduce while the backend is still
//! producing bucket *k+1*. Each bucket runs through the configured
//! [`Collective`] in its own disjoint `tag_span` window, so any number of
//! bucket reductions can be in flight across ranks without cross-talk.
//!
//! Because buckets are tensor-aligned and LARS trust ratios are per-layer,
//! applying each bucket's reduced gradient independently is bit-identical
//! to one whole-model apply; and with `bucket_bytes = 0` the plan is a
//! single bucket whose flat layout, tag window and reduction are exactly
//! the pre-pipeline monolithic path.
//!
//! [`BucketPlan`] is the shape-only schedule (built once per phase);
//! [`BucketStaging`] owns the reusable flat buffers and the received
//! gradient tensors for one in-flight step — reduced values are written
//! back into the tensors the backend shipped, so the steady-state step
//! allocates nothing in this layer.

use anyhow::{anyhow, bail, Result};

use super::primitives::Wire;
use super::transport::Transport;
use super::Collective;
use crate::runtime::HostTensor;

/// One tensor-aligned bucket of the gradient.
#[derive(Debug, Clone)]
pub struct Bucket {
    /// Ascending range of parameter indices this bucket covers.
    pub params: std::ops::Range<usize>,
    /// Total f32 elements across those parameters.
    pub elems: usize,
}

/// The bucket schedule for one parameter table: bucket 0 covers the
/// *last* parameters (first gradients out of the backward pass), the last
/// bucket ends at parameter 0.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    buckets: Vec<Bucket>,
    /// Per parameter: `(bucket index, element offset inside that bucket's
    /// flat buffer)`. Offsets are laid out in ascending parameter order,
    /// matching the monolithic flatten order.
    param_slot: Vec<(usize, usize)>,
    elem_counts: Vec<usize>,
}

impl BucketPlan {
    /// Build the plan: walk parameters from the last index down (the
    /// backward-pass emission order), closing a bucket whenever adding the
    /// next tensor would push it past `bucket_bytes` (4 bytes per element
    /// — the f32 accumulator, not the wire dtype). A tensor larger than
    /// `bucket_bytes` gets a bucket of its own; `bucket_bytes == 0` means
    /// one bucket over everything (the serial, pre-pipeline schedule).
    pub fn new(elem_counts: &[usize], bucket_bytes: usize) -> Self {
        let n = elem_counts.len();
        let mut buckets = Vec::new();
        if n > 0 {
            let mut hi = n;
            let mut acc = 0usize;
            for idx in (0..n).rev() {
                let e = elem_counts[idx];
                if bucket_bytes > 0 && acc > 0 && (acc + e) * 4 > bucket_bytes {
                    buckets.push(Bucket {
                        params: idx + 1..hi,
                        elems: acc,
                    });
                    hi = idx + 1;
                    acc = 0;
                }
                acc += e;
            }
            buckets.push(Bucket {
                params: 0..hi,
                elems: acc,
            });
        }
        let mut param_slot = vec![(0usize, 0usize); n];
        for (b, bucket) in buckets.iter().enumerate() {
            let mut off = 0;
            for idx in bucket.params.clone() {
                param_slot[idx] = (b, off);
                off += elem_counts[idx];
            }
        }
        Self {
            buckets,
            param_slot,
            elem_counts: elem_counts.to_vec(),
        }
    }

    /// Number of buckets.
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    pub fn bucket(&self, k: usize) -> &Bucket {
        &self.buckets[k]
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    pub fn n_params(&self) -> usize {
        self.elem_counts.len()
    }

    /// `(bucket, element offset)` of parameter `idx`.
    pub fn slot(&self, idx: usize) -> Result<(usize, usize)> {
        self.param_slot
            .get(idx)
            .copied()
            .ok_or_else(|| anyhow!("parameter #{idx} outside the bucket plan"))
    }
}

/// Reusable per-rank staging for one in-flight step: flat reduction
/// buffers (one per bucket, allocated once) plus the gradient tensors the
/// backend streamed in (their storage is reused as the apply payload).
#[derive(Debug)]
pub struct BucketStaging {
    flats: Vec<Vec<f32>>,
    tensors: Vec<Option<HostTensor>>,
    received: Vec<usize>,
    placed: usize,
}

impl BucketStaging {
    pub fn new(plan: &BucketPlan) -> Self {
        Self {
            flats: plan.buckets.iter().map(|b| vec![0.0; b.elems]).collect(),
            tensors: vec![None; plan.n_params()],
            received: vec![0; plan.len()],
            placed: 0,
        }
    }

    /// Reset for the next step (flat buffers keep their storage).
    pub fn begin(&mut self) {
        for r in self.received.iter_mut() {
            *r = 0;
        }
        for t in self.tensors.iter_mut() {
            *t = None;
        }
        self.placed = 0;
    }

    /// Account one streamed gradient: copy it into its bucket's flat
    /// buffer (at the monolithic flatten offset) and keep the tensor for
    /// the write-back in [`Self::take_bucket`].
    pub fn place(&mut self, plan: &BucketPlan, idx: usize, t: HostTensor) -> Result<()> {
        let (b, off) = plan.slot(idx)?;
        let want = plan.elem_counts[idx];
        let data = t.as_f32()?;
        if data.len() != want {
            bail!(
                "gradient #{idx} has {} elements, parameter table says {want}",
                data.len()
            );
        }
        if self.tensors[idx].is_some() {
            bail!("gradient #{idx} was streamed twice in one step");
        }
        self.flats[b][off..off + want].copy_from_slice(data);
        self.tensors[idx] = Some(t);
        self.received[b] += 1;
        self.placed += 1;
        Ok(())
    }

    /// Has bucket `k` received all of its gradients?
    pub fn bucket_ready(&self, plan: &BucketPlan, k: usize) -> bool {
        self.received[k] == plan.bucket(k).params.len()
    }

    /// Have all gradients of the step arrived?
    pub fn all_placed(&self, plan: &BucketPlan) -> bool {
        self.placed == plan.n_params()
    }

    /// Bucket `k`'s flat buffer (the all-reduce operand).
    pub fn flat_mut(&mut self, k: usize) -> &mut [f32] {
        &mut self.flats[k]
    }

    /// Move bucket `k`'s tensors out with the (reduced, scaled) flat
    /// values written back into their storage — ascending parameter order,
    /// ready for a partial apply. No allocation: the tensors are the ones
    /// the backend streamed in.
    pub fn take_bucket(&mut self, plan: &BucketPlan, k: usize) -> Result<Vec<HostTensor>> {
        let bucket = plan.bucket(k);
        let flat = &self.flats[k];
        let mut out = Vec::with_capacity(bucket.params.len());
        let mut off = 0;
        for idx in bucket.params.clone() {
            let mut t = self.tensors[idx]
                .take()
                .ok_or_else(|| anyhow!("bucket {k}: gradient #{idx} was never placed"))?;
            let n = plan.elem_counts[idx];
            t.as_f32_mut()?.copy_from_slice(&flat[off..off + n]);
            out.push(t);
            off += n;
        }
        Ok(out)
    }
}

/// All-reduce a set of per-bucket flat buffers through `coll`, bucket `k`
/// offset by `k · tag_span` from `tag_base`. This is the reduction
/// schedule the worker pipeline drives incrementally (it interleaves the
/// same calls with gradient arrival); exposed here so tests can pin the
/// invariant that bucketing is pure orchestration — bit-identical to
/// reducing each bucket through the collective one at a time. Returns the
/// first tag after the last window.
pub fn all_reduce_buckets(
    coll: &dyn Collective,
    ep: &mut dyn Transport,
    bufs: &mut [Vec<f32>],
    wire: Wire,
    tag_base: u64,
) -> Result<u64> {
    let span = coll.tag_span(ep.world_size());
    let mut tag = tag_base;
    for buf in bufs.iter_mut() {
        coll.all_reduce(ep, buf, wire, tag)?;
        tag += span;
    }
    Ok(tag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::test_support::{expected_sum, test_vector};
    use crate::collectives::transport::Mesh;
    use crate::collectives::TorusAllReduce;
    use crate::util::quickcheck::{prop_seeded, Gen};
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn plan_covers_every_param_exactly_once() {
        prop_seeded(0xB0C4_E7ED, 40, |g: &mut Gen| {
            let n = g.usize_in(1..=40);
            let counts: Vec<usize> = (0..n).map(|_| g.usize_in(1..=5000)).collect();
            let bytes = *g.choose(&[0usize, 64, 1024, 8192, 1 << 20]);
            let plan = BucketPlan::new(&counts, bytes);
            // ascending-from-the-end, disjoint, complete coverage
            assert_eq!(plan.bucket(plan.len() - 1).params.start, 0);
            assert_eq!(plan.bucket(0).params.end, n);
            for w in plan.buckets().windows(2) {
                assert_eq!(w[1].params.end, w[0].params.start, "gap/overlap");
            }
            let total: usize = plan.buckets().iter().map(|b| b.elems).sum();
            assert_eq!(total, counts.iter().sum::<usize>());
            for (k, b) in plan.buckets().iter().enumerate() {
                assert!(!b.params.is_empty());
                let elems: usize = counts[b.params.clone()].iter().sum();
                assert_eq!(elems, b.elems);
                // target respected unless the bucket is a single big tensor
                if bytes > 0 && b.params.len() > 1 {
                    assert!(b.elems * 4 <= bytes, "bucket {k} oversize");
                }
            }
            if bytes == 0 {
                assert_eq!(plan.len(), 1, "0 = the single serial bucket");
            }
            // slots are ascending within each bucket and land inside it
            for idx in 0..n {
                let (b, off) = plan.slot(idx).unwrap();
                assert!(plan.bucket(b).params.contains(&idx));
                assert!(off + counts[idx] <= plan.bucket(b).elems);
            }
        });
    }

    fn split_by_plan(plan: &BucketPlan, full: &[f32], counts: &[usize]) -> Vec<Vec<f32>> {
        // per-param offsets in the monolithic flat layout
        let mut offs = Vec::with_capacity(counts.len() + 1);
        offs.push(0usize);
        for c in counts {
            offs.push(offs.last().unwrap() + c);
        }
        plan.buckets()
            .iter()
            .map(|b| full[offs[b.params.start]..offs[b.params.end]].to_vec())
            .collect()
    }

    /// Random grid × random bucket size × both wires: the bucketed
    /// reduction (disjoint tag windows, deliberately skewed rank timing)
    /// is bit-identical on every rank to reducing each bucket through the
    /// plain collective one at a time, all ranks agree bitwise, and the
    /// result matches the exact sum within wire tolerance.
    #[test]
    fn bucketed_matches_serial_per_bucket_bitwise() {
        prop_seeded(0xB0C4_0123, 12, |g: &mut Gen| {
            let x = g.usize_in(1..=3);
            let y = g.usize_in(1..=3);
            let n = x * y;
            let elems = g.usize_in(1..=400);
            let counts = {
                // random tensor-aligned split of `elems`
                let mut left = elems;
                let mut c = Vec::new();
                while left > 0 {
                    let take = g.usize_in(1..=left.min(64));
                    c.push(take);
                    left -= take;
                }
                c
            };
            let bytes = *g.choose(&[0usize, 64, 256, 4096]);
            let wire = *g.choose(&[Wire::F32, Wire::F16]);
            let plan = Arc::new(BucketPlan::new(&counts, bytes));
            let coll = TorusAllReduce::new(x, y);

            // bucketed run, ranks deliberately skewed so several buckets
            // are in flight across ranks at once
            let eps = Mesh::new(n);
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    let plan = plan.clone();
                    let counts = counts.clone();
                    thread::spawn(move || {
                        let rank = ep.rank();
                        std::thread::sleep(std::time::Duration::from_micros(
                            (rank as u64) * 300,
                        ));
                        let full = test_vector(rank, counts.iter().sum());
                        let mut bufs = split_by_plan(&plan, &full, &counts);
                        all_reduce_buckets(&coll, &mut ep, &mut bufs, wire, 0).unwrap();
                        bufs
                    })
                })
                .collect();
            let bucketed: Vec<Vec<Vec<f32>>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();

            // serial reference: each bucket reduced on its own fresh mesh
            let mut serial: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
            for k in 0..plan.len() {
                let eps = Mesh::new(n);
                let handles: Vec<_> = eps
                    .into_iter()
                    .map(|mut ep| {
                        let plan = plan.clone();
                        let counts = counts.clone();
                        thread::spawn(move || {
                            let full = test_vector(ep.rank(), counts.iter().sum());
                            let mut buf = split_by_plan(&plan, &full, &counts)[k].clone();
                            coll.all_reduce(&mut ep, &mut buf, wire, 0).unwrap();
                            buf
                        })
                    })
                    .collect();
                for (rank, h) in handles.into_iter().enumerate() {
                    serial[rank].push(h.join().unwrap());
                }
            }

            for rank in 0..n {
                assert_eq!(
                    bucketed[rank], serial[rank],
                    "rank {rank}: pipelined bucketing changed the numerics"
                );
                assert_eq!(bucketed[rank], bucketed[0], "ranks disagree");
            }

            // and the concatenation approximates the exact sum
            let want = expected_sum(n, elems);
            let got: Vec<f32> = bucketed[0].iter().flatten().copied().collect();
            for (gv, wv) in got.iter().zip(&want) {
                let tol = if wire == Wire::F16 {
                    (wv.abs() * 5e-3).max(1e-3)
                } else {
                    (wv.abs() * 1e-3).max(1e-4)
                };
                assert!((gv - wv).abs() < tol, "{gv} vs {wv}");
            }
        });
    }

    /// Byte-counter bridge: bucketing does not change the data volume the
    /// collective moves (chosen sizes divide evenly so the per-phase
    /// formula is exact) — the functional counters stay aligned with the
    /// analytic cost model whether or not the pipeline is on.
    #[test]
    fn bucketing_conserves_wire_bytes() {
        let (x, y) = (4usize, 2usize);
        let n = x * y;
        let coll = TorusAllReduce::new(x, y);
        // 3 buckets of 96 elements each: 96 divides by x and x*y
        let counts = vec![96usize, 96, 96];
        let run = |bytes: usize| -> (u64, u64) {
            let plan = Arc::new(BucketPlan::new(&counts, bytes));
            let counts = counts.clone();
            let eps = Mesh::new(n);
            let counters = eps[0].counters_arc();
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    let plan = plan.clone();
                    let counts = counts.clone();
                    thread::spawn(move || {
                        let full = test_vector(ep.rank(), counts.iter().sum());
                        let mut bufs = split_by_plan(&plan, &full, &counts);
                        all_reduce_buckets(&coll, &mut ep, &mut bufs, Wire::F32, 0).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let (sent, recvd, _) = counters.snapshot();
            (sent, recvd)
        };
        let (mono_sent, mono_recvd) = run(0);
        let (buck_sent, buck_recvd) = run(96 * 4); // one tensor per bucket
        assert_eq!(mono_sent, mono_recvd, "byte conservation (monolithic)");
        assert_eq!(buck_sent, buck_recvd, "byte conservation (bucketed)");
        assert_eq!(
            mono_sent, buck_sent,
            "bucketing must not change total wire volume"
        );
        // and the volume matches the torus formula per rank
        let elems = 96 * 3;
        let per_rank = (x - 1) * (elems / x) * 2 + 2 * (y - 1) * (elems / (x * y));
        assert_eq!(mono_sent, (n * per_rank * 4) as u64);
    }

    #[test]
    fn staging_round_trip_reuses_tensor_storage() {
        let counts = vec![4usize, 2, 3];
        let plan = BucketPlan::new(&counts, 12); // -> buckets [{2}, {1}, {0}] sized 3,2,4...
        let mut staging = BucketStaging::new(&plan);
        staging.begin();
        // stream in reverse param order, remembering storage addresses
        let mut ptrs = Vec::new();
        for idx in (0..3).rev() {
            let t = HostTensor::f32(
                vec![counts[idx]],
                (0..counts[idx]).map(|j| (idx * 10 + j) as f32).collect(),
            );
            ptrs.push((idx, t.as_f32().unwrap().as_ptr()));
            staging.place(&plan, idx, t).unwrap();
        }
        assert!(staging.all_placed(&plan));
        for k in 0..plan.len() {
            assert!(staging.bucket_ready(&plan, k));
            // pretend-reduce: double everything
            for v in staging.flat_mut(k) {
                *v *= 2.0;
            }
            let tensors = staging.take_bucket(&plan, k).unwrap();
            for t in &tensors {
                let data = t.as_f32().unwrap();
                let ptr = data.as_ptr();
                assert!(
                    ptrs.iter().any(|&(_, p)| p == ptr),
                    "take_bucket must hand back the streamed tensors' storage"
                );
                // values are the reduced flat values
                for v in data {
                    assert_eq!((*v / 2.0).fract(), 0.0);
                }
            }
        }
        // double placement is rejected
        staging.begin();
        staging
            .place(&plan, 1, HostTensor::f32(vec![2], vec![0.0; 2]))
            .unwrap();
        assert!(staging
            .place(&plan, 1, HostTensor::f32(vec![2], vec![0.0; 2]))
            .is_err());
        // wrong size is rejected
        assert!(staging
            .place(&plan, 0, HostTensor::f32(vec![1], vec![0.0]))
            .is_err());
    }
}
