//! In-memory rank mesh: the substrate under every collective.
//!
//! This sits where NCCL + MPI sit in the paper's stack. [`Mesh::new(n)`]
//! builds `n` fully-connected [`Endpoint`]s; each worker thread owns one and
//! exchanges tagged messages with any peer. Channels are unbounded, so sends
//! never block and ring schedules cannot deadlock; receives block until the
//! matching `(src, tag)` message arrives (out-of-order arrivals are parked in
//! a pending map, as in MPI tag matching).
//!
//! Every endpoint keeps byte/message counters. Tests use them to check
//! *conservation* (total sent == total received) and to verify each
//! collective moves exactly the data volume its cost model claims —
//! the bridge between the functional path and `simnet`'s analytical path.
//!
//! Endpoints also keep per-dtype **scratch freelists**: receive paths hand
//! consumed payload storage back ([`Endpoint::recycle`]) and send paths
//! draw from it ([`Endpoint::alloc_f16`], and [`Endpoint::send_f32`]
//! internally), so the bucketed gradient pipeline's much higher message
//! rate does not translate into per-hop allocation churn.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

/// Wire payload. FP32 is the paper's BN-stat path; FP16 the gradient path.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl Payload {
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::F32(v) => 4 * v.len() as u64,
            Payload::F16(v) => 2 * v.len() as u64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug)]
struct Msg {
    src: usize,
    tag: u64,
    payload: Payload,
}

/// Shared per-mesh traffic counters (lock-free).
#[derive(Debug, Default)]
pub struct Counters {
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
    pub messages: AtomicU64,
    /// Highest tag any rank has sent with — lets tests verify that a
    /// collective stays inside its declared `tag_span` window.
    pub max_tag: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.bytes_sent.load(Ordering::Relaxed),
            self.bytes_received.load(Ordering::Relaxed),
            self.messages.load(Ordering::Relaxed),
        )
    }

    /// Highest tag observed on any send since the last reset.
    pub fn max_tag_seen(&self) -> u64 {
        self.max_tag.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.bytes_received.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
        self.max_tag.store(0, Ordering::Relaxed);
    }
}

/// Factory for a fully-connected mesh of `n` endpoints.
pub struct Mesh;

impl Mesh {
    /// Build `n` endpoints sharing one counter block.
    pub fn new(n: usize) -> Vec<Endpoint> {
        assert!(n > 0, "mesh needs at least one rank");
        let counters = Arc::new(Counters::default());
        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(n);
        let mut receivers: Vec<Receiver<Msg>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint {
                rank,
                n,
                senders: senders.clone(),
                rx,
                pending: HashMap::new(),
                counters: counters.clone(),
                free_f32: Vec::new(),
                free_f16: Vec::new(),
                freelist_hits: 0,
            })
            .collect()
    }
}

/// One rank's view of the mesh (owned by that rank's worker thread).
pub struct Endpoint {
    rank: usize,
    n: usize,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order arrivals parked per `(src, tag)`. `VecDeque` keeps
    /// pops O(1) under bursts (a `Vec::remove(0)` here is O(n) per pop —
    /// quadratic when a peer runs ahead), and entries are removed as soon
    /// as they drain so the map cannot grow without bound across a run.
    pending: HashMap<(usize, u64), VecDeque<Payload>>,
    counters: Arc<Counters>,
    /// Scratch-buffer freelists. Receive paths recycle consumed payload
    /// storage here; send paths draw from it instead of allocating per
    /// hop. In a steady ring schedule each rank receives about as much as
    /// it sends, so buffers circulate recv → freelist → next send and the
    /// per-hop allocation rate drops to ~zero after warmup.
    free_f32: Vec<Vec<f32>>,
    free_f16: Vec<Vec<u16>>,
    freelist_hits: u64,
}

/// Upper bound on parked scratch buffers per dtype (bounds memory when a
/// caller recycles far more than it sends).
const FREELIST_CAP: usize = 32;

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.n
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Shared counter block (snapshot it *after* joining all rank threads —
    /// per-thread snapshots race with peers still in flight).
    pub fn counters_arc(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// Send `payload` to `dst` under `tag`. Never blocks.
    pub fn send(&self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        let bytes = payload.wire_bytes();
        self.senders
            .get(dst)
            .ok_or_else(|| anyhow!("send to out-of-range rank {dst} (n={})", self.n))?
            .send(Msg {
                src: self.rank,
                tag,
                payload,
            })
            .map_err(|_| anyhow!("rank {dst} hung up (worker thread died?)"))?;
        self.counters.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.max_tag.fetch_max(tag, Ordering::Relaxed);
        Ok(())
    }

    /// Copy `data` into a freelist-backed buffer and send it (no per-hop
    /// allocation once the freelist has warmed up).
    pub fn send_f32(&mut self, dst: usize, tag: u64, data: &[f32]) -> Result<()> {
        let mut buf = self.alloc_f32(data.len());
        buf.extend_from_slice(data);
        self.send(dst, tag, Payload::F32(buf))
    }

    pub fn send_f16(&self, dst: usize, tag: u64, data: Vec<u16>) -> Result<()> {
        self.send(dst, tag, Payload::F16(data))
    }

    /// Take an **empty** f32 scratch buffer with at least `capacity_hint`
    /// reserved — from the freelist when one is parked, freshly allocated
    /// otherwise.
    pub fn alloc_f32(&mut self, capacity_hint: usize) -> Vec<f32> {
        match self.free_f32.pop() {
            Some(mut v) => {
                self.freelist_hits += 1;
                v.clear();
                v.reserve(capacity_hint);
                v
            }
            None => Vec::with_capacity(capacity_hint),
        }
    }

    /// Take a zero-filled f16 scratch buffer of exactly `len` elements.
    /// Recycled buffers are cleared before resizing, so a longer previous
    /// payload can never leak a stale tail into a shorter message.
    pub fn alloc_f16(&mut self, len: usize) -> Vec<u16> {
        let mut v = match self.free_f16.pop() {
            Some(v) => {
                self.freelist_hits += 1;
                v
            }
            None => Vec::new(),
        };
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Park a consumed f32 buffer for reuse by a later send/receive.
    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.free_f32.len() < FREELIST_CAP {
            self.free_f32.push(v);
        }
    }

    /// Park a consumed f16 buffer for reuse by a later send/receive.
    pub fn recycle_f16(&mut self, v: Vec<u16>) {
        if self.free_f16.len() < FREELIST_CAP {
            self.free_f16.push(v);
        }
    }

    /// Park a consumed payload's storage whatever its dtype.
    pub fn recycle(&mut self, p: Payload) {
        match p {
            Payload::F32(v) => self.recycle_f32(v),
            Payload::F16(v) => self.recycle_f16(v),
        }
    }

    /// How many scratch buffers were served from the freelist instead of
    /// the allocator (observability for the reuse tests).
    pub fn freelist_hits(&self) -> u64 {
        self.freelist_hits
    }

    /// Blocking receive of the message matching `(src, tag)`.
    ///
    /// Messages from other (src, tag) pairs arriving first are parked and
    /// delivered to their own matching receive later (MPI-style matching).
    pub fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        let key = (src, tag);
        if let Entry::Occupied(mut e) = self.pending.entry(key) {
            // queues are dropped when drained, so an entry is never empty
            let p = e.get_mut().pop_front().expect("empty pending queue kept");
            if e.get().is_empty() {
                e.remove();
            }
            self.counters
                .bytes_received
                .fetch_add(p.wire_bytes(), Ordering::Relaxed);
            return Ok(p);
        }
        loop {
            let msg = self
                .rx
                .recv()
                .map_err(|_| anyhow!("rank {}: all peers hung up", self.rank))?;
            if msg.src == src && msg.tag == tag {
                self.counters
                    .bytes_received
                    .fetch_add(msg.payload.wire_bytes(), Ordering::Relaxed);
                return Ok(msg.payload);
            }
            self.pending
                .entry((msg.src, msg.tag))
                .or_default()
                .push_back(msg.payload);
        }
    }

    /// Number of parked out-of-order messages (tests assert this drains to
    /// zero so the pending map cannot leak across a long run).
    pub fn pending_messages(&self) -> usize {
        self.pending.values().map(|q| q.len()).sum()
    }

    /// Receive and require an f32 payload (wire-format mismatch is a bug).
    pub fn recv_f32(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        match self.recv(src, tag)? {
            Payload::F32(v) => Ok(v),
            Payload::F16(_) => Err(anyhow!(
                "rank {}: expected f32 wire payload from {src} tag {tag}, got f16",
                self.rank
            )),
        }
    }

    /// Receive and require an f16 payload.
    pub fn recv_f16(&mut self, src: usize, tag: u64) -> Result<Vec<u16>> {
        match self.recv(src, tag)? {
            Payload::F16(v) => Ok(v),
            Payload::F32(_) => Err(anyhow!(
                "rank {}: expected f16 wire payload from {src} tag {tag}, got f32",
                self.rank
            )),
        }
    }
}

impl std::fmt::Debug for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Endpoint")
            .field("rank", &self.rank)
            .field("n", &self.n)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_round_trip() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 7, &[1.0, 2.0, 3.0]).unwrap();
        let got = b.recv_f32(0, 7).unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 1, &[1.0]).unwrap();
        a.send_f32(1, 2, &[2.0]).unwrap();
        a.send_f32(1, 1, &[3.0]).unwrap();
        // Receive tag 2 first; tag-1 messages must stay queued in order.
        assert_eq!(b.recv_f32(0, 2).unwrap(), vec![2.0]);
        assert_eq!(b.recv_f32(0, 1).unwrap(), vec![1.0]);
        assert_eq!(b.recv_f32(0, 1).unwrap(), vec![3.0]);
    }

    #[test]
    fn byte_conservation_across_threads() {
        let n = 4;
        let eps = Mesh::new(n);
        let counters = eps[0].counters.clone();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let me = ep.rank();
                    let right = (me + 1) % 4;
                    let left = (me + 3) % 4;
                    for step in 0..10u64 {
                        ep.send_f32(right, step, &vec![me as f32; 100]).unwrap();
                        let got = ep.recv_f32(left, step).unwrap();
                        assert_eq!(got, vec![left as f32; 100]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (sent, recvd, msgs) = counters.snapshot();
        assert_eq!(sent, recvd);
        assert_eq!(sent, 4 * 10 * 100 * 4); // ranks * steps * elems * 4B
        assert_eq!(msgs, 40);
    }

    #[test]
    fn pending_queue_drains_and_entries_are_dropped() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // out-of-order burst: many messages on tags received later
        for i in 0..50u64 {
            a.send_f32(1, i % 5, &[i as f32]).unwrap();
        }
        a.send_f32(1, 99, &[99.0]).unwrap();
        // receiving tag 99 first parks all 50 burst messages
        assert_eq!(b.recv_f32(0, 99).unwrap(), vec![99.0]);
        assert_eq!(b.pending_messages(), 50);
        // drain them in FIFO order per tag
        for i in 0..50u64 {
            let tag = i % 5;
            let got = b.recv_f32(0, tag).unwrap();
            // per-tag order: the k-th receive of `tag` is message 5k+tag
            assert_eq!(got, vec![(5 * (i / 5) + tag) as f32], "tag {tag}");
        }
        // fully drained: no empty queues linger in the map
        assert_eq!(b.pending_messages(), 0);
        assert!(b.pending.is_empty(), "empty pending entries leaked");
    }

    #[test]
    fn f16_payload_counts_two_bytes() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.send_f16(1, 0, vec![0x3C00; 8]).unwrap();
        let got = b.recv_f16(0, 0).unwrap();
        assert_eq!(got.len(), 8);
        let (sent, _, _) = a.counters().snapshot();
        assert_eq!(sent, 16);
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 0, &[1.0]).unwrap();
        assert!(b.recv_f16(0, 0).is_err());
    }

    #[test]
    fn send_out_of_range_is_error() {
        let mut eps = Mesh::new(2);
        assert!(eps[0].send_f32(5, 0, &[1.0]).is_err());
    }

    /// The freelist must never hand back a stale payload: a recycled long
    /// buffer reused for a shorter message carries exactly the new bytes —
    /// no leftover tail, no leftover length.
    #[test]
    fn freelist_never_hands_back_stale_payloads() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();

        // f32: long payload recycled on b, then b sends a short one.
        a.send_f32(1, 0, &[9.0; 64]).unwrap();
        let long = b.recv_f32(0, 0).unwrap();
        assert_eq!(long.len(), 64);
        b.recycle_f32(long);
        b.send_f32(0, 1, &[1.0, 2.0]).unwrap();
        assert!(b.freelist_hits() >= 1, "short send must hit the freelist");
        assert_eq!(a.recv_f32(1, 1).unwrap(), vec![1.0, 2.0]);

        // f16: alloc after recycling a longer buffer is exact-length and
        // zero-filled, not a truncated view of the old contents.
        a.send_f16(1, 2, vec![7u16; 50]).unwrap();
        let enc = b.recv_f16(0, 2).unwrap();
        b.recycle_f16(enc);
        let mut short = b.alloc_f16(3);
        assert_eq!(short, vec![0u16; 3]);
        short.copy_from_slice(&[1, 2, 3]);
        b.send_f16(0, 3, short).unwrap();
        assert_eq!(a.recv_f16(1, 3).unwrap(), vec![1, 2, 3]);

        // the cap bounds parked buffers
        for _ in 0..100 {
            b.recycle_f32(vec![0.0; 4]);
        }
        assert!(b.free_f32.len() <= FREELIST_CAP);
    }
}
