//! In-memory rank mesh: the substrate under every collective.
//!
//! This sits where NCCL + MPI sit in the paper's stack. [`Mesh::new(n)`]
//! builds `n` fully-connected [`Endpoint`]s; each worker thread owns one and
//! exchanges tagged messages with any peer. Channels are unbounded, so sends
//! never block and ring schedules cannot deadlock; receives block until the
//! matching `(src, tag)` message arrives (out-of-order arrivals are parked in
//! a pending map, as in MPI tag matching).
//!
//! Every endpoint keeps byte/message counters. Tests use them to check
//! *conservation* (total sent == total received) and to verify each
//! collective moves exactly the data volume its cost model claims —
//! the bridge between the functional path and `simnet`'s analytical path.
//!
//! Endpoints also keep per-dtype **scratch freelists**: receive paths hand
//! consumed payload storage back ([`Endpoint::recycle`]) and send paths
//! draw from it ([`Endpoint::alloc_f16`], and [`Endpoint::send_f32`]
//! internally), so the bucketed gradient pipeline's much higher message
//! rate does not translate into per-hop allocation churn.
//!
//! **Fault path**: every mesh shares one [`Health`] table. A rank (or the
//! coordinator's heartbeat monitor) can [`Health::mark_dead`] a peer; that
//! raises a mesh-wide abort flag, and every blocked `recv` — which waits in
//! bounded ticks, never indefinitely — unwinds with a typed [`MeshError`]
//! instead of deadlocking. This is what makes a dead rank mid-collective a
//! recoverable event rather than a process-wide hang.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context as _, Result};

/// Typed transport fault. Collectives propagate these through their normal
/// `Result` paths, so a worker can distinguish *being* the failure (a real
/// local error) from being a **victim** of a peer's death / a phase abort
/// (`anyhow`'s `downcast_ref::<MeshError>` finds it through any context
/// chain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshError {
    /// The peer this rank was waiting on (or sending to) is marked dead.
    PeerDead { rank: usize },
    /// The mesh-wide abort flag is up; `origin` is the first rank marked
    /// dead (the death that triggered the abort).
    Aborted { origin: usize },
}

impl std::fmt::Display for MeshError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MeshError::PeerDead { rank } => write!(f, "peer rank {rank} is dead"),
            MeshError::Aborted { origin } => {
                write!(f, "collective aborted (first dead rank: {origin})")
            }
        }
    }
}

impl std::error::Error for MeshError {}

/// Wait granularity of the bounded `recv` loop: how often a blocked
/// receive re-checks the health table (and ticks its own heartbeat).
const RECV_TICK: Duration = Duration::from_millis(1);

/// Shared per-mesh health table: heartbeats, per-rank liveness, and the
/// mesh-wide abort flag. One per [`Mesh`]; every [`Endpoint`] holds it, and
/// the coordinator's heartbeat monitor scans it from outside the mesh.
#[derive(Debug)]
pub struct Health {
    start: Instant,
    /// Millis-since-`start` of each rank's last heartbeat.
    beats: Vec<AtomicU64>,
    /// Ranks whose worker thread has exited — cleanly *or* by
    /// erroring/panicking out. They stop beating legitimately; the
    /// heartbeat monitor must not confuse any of them with hung ranks
    /// (whether an exited rank was a casualty is what `dead` records).
    done: Vec<AtomicBool>,
    dead: Vec<AtomicBool>,
    abort: AtomicBool,
    /// First rank marked dead (`usize::MAX` = none yet).
    first_dead: AtomicUsize,
}

impl Health {
    fn new(n: usize) -> Self {
        Self {
            start: Instant::now(),
            beats: (0..n).map(|_| AtomicU64::new(0)).collect(),
            done: (0..n).map(|_| AtomicBool::new(false)).collect(),
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            abort: AtomicBool::new(false),
            first_dead: AtomicUsize::new(usize::MAX),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.dead.len()
    }

    /// Record a liveness tick for `rank`.
    pub fn beat(&self, rank: usize) {
        let ms = self.start.elapsed().as_millis() as u64;
        self.beats[rank].store(ms, Ordering::Relaxed);
    }

    /// Millis since `rank`'s last heartbeat.
    pub fn millis_since_beat(&self, rank: usize) -> u64 {
        let now = self.start.elapsed().as_millis() as u64;
        now.saturating_sub(self.beats[rank].load(Ordering::Relaxed))
    }

    /// Mark `rank`'s worker thread as exited (cleanly or not): the monitor
    /// stops expecting heartbeats from it.
    pub fn mark_done(&self, rank: usize) {
        self.done[rank].store(true, Ordering::Release);
    }

    pub fn is_done(&self, rank: usize) -> bool {
        self.done[rank].load(Ordering::Acquire)
    }

    /// Declare `rank` dead. Raises the mesh-wide abort flag, so every
    /// in-flight `recv` on every surviving rank unwinds within one
    /// [`RECV_TICK`] instead of waiting on a message that will never come.
    pub fn mark_dead(&self, rank: usize) {
        self.dead[rank].store(true, Ordering::Release);
        let _ = self.first_dead.compare_exchange(
            usize::MAX,
            rank,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        self.abort.store(true, Ordering::Release);
    }

    pub fn is_dead(&self, rank: usize) -> bool {
        self.dead[rank].load(Ordering::Acquire)
    }

    pub fn aborted(&self) -> bool {
        self.abort.load(Ordering::Acquire)
    }

    /// The rank whose death triggered the abort, if any.
    pub fn first_dead(&self) -> Option<usize> {
        match self.first_dead.load(Ordering::Acquire) {
            usize::MAX => None,
            r => Some(r),
        }
    }

    /// All ranks currently marked dead.
    pub fn dead_ranks(&self) -> Vec<usize> {
        (0..self.dead.len()).filter(|&r| self.is_dead(r)).collect()
    }

    /// Fault check on the `src → this rank` edge: errors once `src` is
    /// dead or the mesh is aborting.
    fn check_edge(&self, src: usize) -> Result<(), MeshError> {
        if self.is_dead(src) {
            return Err(MeshError::PeerDead { rank: src });
        }
        if self.aborted() {
            return Err(MeshError::Aborted {
                origin: self.first_dead().unwrap_or(usize::MAX),
            });
        }
        Ok(())
    }
}

/// Wire payload. FP32 is the paper's BN-stat path; FP16 the gradient path.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl Payload {
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::F32(v) => 4 * v.len() as u64,
            Payload::F16(v) => 2 * v.len() as u64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug)]
struct Msg {
    src: usize,
    tag: u64,
    payload: Payload,
}

/// Shared per-mesh traffic counters (lock-free).
#[derive(Debug, Default)]
pub struct Counters {
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
    pub messages: AtomicU64,
    /// Highest tag any rank has sent with — lets tests verify that a
    /// collective stays inside its declared `tag_span` window.
    pub max_tag: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.bytes_sent.load(Ordering::Relaxed),
            self.bytes_received.load(Ordering::Relaxed),
            self.messages.load(Ordering::Relaxed),
        )
    }

    /// Highest tag observed on any send since the last reset.
    pub fn max_tag_seen(&self) -> u64 {
        self.max_tag.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.bytes_received.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
        self.max_tag.store(0, Ordering::Relaxed);
    }
}

/// Factory for a fully-connected mesh of `n` endpoints.
pub struct Mesh;

impl Mesh {
    /// Build `n` endpoints sharing one counter block and one health table.
    pub fn new(n: usize) -> Vec<Endpoint> {
        assert!(n > 0, "mesh needs at least one rank");
        let counters = Arc::new(Counters::default());
        let health = Arc::new(Health::new(n));
        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(n);
        let mut receivers: Vec<Receiver<Msg>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint {
                rank,
                n,
                senders: senders.clone(),
                rx,
                pending: HashMap::new(),
                counters: counters.clone(),
                health: health.clone(),
                recv_deadline: None,
                free_f32: Vec::new(),
                free_f16: Vec::new(),
                freelist_hits: 0,
            })
            .collect()
    }
}

/// One rank's view of the mesh (owned by that rank's worker thread).
pub struct Endpoint {
    rank: usize,
    n: usize,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order arrivals parked per `(src, tag)`. `VecDeque` keeps
    /// pops O(1) under bursts (a `Vec::remove(0)` here is O(n) per pop —
    /// quadratic when a peer runs ahead), and entries are removed as soon
    /// as they drain so the map cannot grow without bound across a run.
    pending: HashMap<(usize, u64), VecDeque<Payload>>,
    counters: Arc<Counters>,
    /// Shared health/abort table (see [`Health`]). `recv` consults it every
    /// [`RECV_TICK`] while blocked, so a dead peer or a phase abort unwinds
    /// the collective instead of hanging it.
    health: Arc<Health>,
    /// Hard per-`recv` wait bound. `None` (the default) means wait until
    /// the health table says otherwise; the coordinator sets it to the
    /// fault config's `rank_timeout` as a belt-and-braces bound against
    /// undetected hangs.
    recv_deadline: Option<Duration>,
    /// Scratch-buffer freelists. Receive paths recycle consumed payload
    /// storage here; send paths draw from it instead of allocating per
    /// hop. In a steady ring schedule each rank receives about as much as
    /// it sends, so buffers circulate recv → freelist → next send and the
    /// per-hop allocation rate drops to ~zero after warmup.
    free_f32: Vec<Vec<f32>>,
    free_f16: Vec<Vec<u16>>,
    freelist_hits: u64,
}

/// Upper bound on parked scratch buffers per dtype (bounds memory when a
/// caller recycles far more than it sends).
const FREELIST_CAP: usize = 32;

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.n
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Shared counter block (snapshot it *after* joining all rank threads —
    /// per-thread snapshots race with peers still in flight).
    pub fn counters_arc(&self) -> Arc<Counters> {
        self.counters.clone()
    }

    /// Shared health table of this endpoint's mesh (the coordinator's
    /// heartbeat monitor scans it; tests use it to kill ranks).
    pub fn health(&self) -> &Health {
        &self.health
    }

    pub fn health_arc(&self) -> Arc<Health> {
        self.health.clone()
    }

    /// Tick this rank's heartbeat (also ticked automatically while blocked
    /// in `recv` — call it once per step so compute-heavy gaps still beat).
    pub fn heartbeat(&self) {
        self.health.beat(self.rank);
    }

    /// Declare a peer (or this rank itself) dead; aborts the whole mesh.
    pub fn mark_dead(&self, rank: usize) {
        self.health.mark_dead(rank);
    }

    /// Bound every subsequent blocking `recv` to `d` of wall-clock wait;
    /// on expiry the awaited peer is marked dead and the receive fails
    /// with [`MeshError::PeerDead`]. `None` removes the bound.
    pub fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.recv_deadline = d;
    }

    /// Send `payload` to `dst` under `tag`. Never blocks; fails fast when
    /// `dst` is already marked dead or the mesh is aborting.
    pub fn send(&self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        if dst < self.n {
            self.health
                .check_edge(dst)
                .map_err(anyhow::Error::new)
                .with_context(|| format!("rank {} send to {dst}", self.rank))?;
        }
        let bytes = payload.wire_bytes();
        self.senders
            .get(dst)
            .ok_or_else(|| anyhow!("send to out-of-range rank {dst} (n={})", self.n))?
            .send(Msg {
                src: self.rank,
                tag,
                payload,
            })
            .map_err(|_| anyhow!("rank {dst} hung up (worker thread died?)"))?;
        self.counters.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.max_tag.fetch_max(tag, Ordering::Relaxed);
        Ok(())
    }

    /// Copy `data` into a freelist-backed buffer and send it (no per-hop
    /// allocation once the freelist has warmed up).
    pub fn send_f32(&mut self, dst: usize, tag: u64, data: &[f32]) -> Result<()> {
        let mut buf = self.alloc_f32(data.len());
        buf.extend_from_slice(data);
        self.send(dst, tag, Payload::F32(buf))
    }

    pub fn send_f16(&self, dst: usize, tag: u64, data: Vec<u16>) -> Result<()> {
        self.send(dst, tag, Payload::F16(data))
    }

    /// Take an **empty** f32 scratch buffer with at least `capacity_hint`
    /// reserved — from the freelist when one is parked, freshly allocated
    /// otherwise.
    pub fn alloc_f32(&mut self, capacity_hint: usize) -> Vec<f32> {
        match self.free_f32.pop() {
            Some(mut v) => {
                self.freelist_hits += 1;
                v.clear();
                v.reserve(capacity_hint);
                v
            }
            None => Vec::with_capacity(capacity_hint),
        }
    }

    /// Take a zero-filled f16 scratch buffer of exactly `len` elements.
    /// Recycled buffers are cleared before resizing, so a longer previous
    /// payload can never leak a stale tail into a shorter message.
    pub fn alloc_f16(&mut self, len: usize) -> Vec<u16> {
        let mut v = match self.free_f16.pop() {
            Some(v) => {
                self.freelist_hits += 1;
                v
            }
            None => Vec::new(),
        };
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Park a consumed f32 buffer for reuse by a later send/receive.
    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.free_f32.len() < FREELIST_CAP {
            self.free_f32.push(v);
        }
    }

    /// Park a consumed f16 buffer for reuse by a later send/receive.
    pub fn recycle_f16(&mut self, v: Vec<u16>) {
        if self.free_f16.len() < FREELIST_CAP {
            self.free_f16.push(v);
        }
    }

    /// Park a consumed payload's storage whatever its dtype.
    pub fn recycle(&mut self, p: Payload) {
        match p {
            Payload::F32(v) => self.recycle_f32(v),
            Payload::F16(v) => self.recycle_f16(v),
        }
    }

    /// How many scratch buffers were served from the freelist instead of
    /// the allocator (observability for the reuse tests).
    pub fn freelist_hits(&self) -> u64 {
        self.freelist_hits
    }

    /// Blocking receive of the message matching `(src, tag)` — but never
    /// an *unbounded* block: the wait runs in [`RECV_TICK`] slices, each of
    /// which re-checks the shared health table (and ticks this rank's own
    /// heartbeat), so a dead peer or a mesh abort surfaces as a typed
    /// [`MeshError`] within one tick instead of deadlocking the collective.
    ///
    /// Messages from other (src, tag) pairs arriving first are parked and
    /// delivered to their own matching receive later (MPI-style matching).
    pub fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        self.health
            .check_edge(src)
            .map_err(anyhow::Error::new)
            .with_context(|| format!("rank {} recv from {src} tag {tag}", self.rank))?;
        let key = (src, tag);
        if let Entry::Occupied(mut e) = self.pending.entry(key) {
            // queues are dropped when drained, so an entry is never empty
            let p = e.get_mut().pop_front().expect("empty pending queue kept");
            if e.get().is_empty() {
                e.remove();
            }
            self.counters
                .bytes_received
                .fetch_add(p.wire_bytes(), Ordering::Relaxed);
            return Ok(p);
        }
        let deadline = self.recv_deadline.map(|d| Instant::now() + d);
        loop {
            match self.rx.recv_timeout(RECV_TICK) {
                Ok(msg) => {
                    if msg.src == src && msg.tag == tag {
                        self.counters
                            .bytes_received
                            .fetch_add(msg.payload.wire_bytes(), Ordering::Relaxed);
                        return Ok(msg.payload);
                    }
                    self.pending
                        .entry((msg.src, msg.tag))
                        .or_default()
                        .push_back(msg.payload);
                }
                Err(RecvTimeoutError::Timeout) => {
                    // Still waiting: we are alive (beat), but is the peer?
                    self.health.beat(self.rank);
                    self.health
                        .check_edge(src)
                        .map_err(anyhow::Error::new)
                        .with_context(|| {
                            format!("rank {} recv from {src} tag {tag}", self.rank)
                        })?;
                    if let Some(dl) = deadline {
                        if Instant::now() >= dl {
                            // The peer outlasted the hard bound: declare it
                            // dead so the rest of the mesh unwinds too.
                            self.health.mark_dead(src);
                            return Err(anyhow::Error::new(MeshError::PeerDead {
                                rank: src,
                            }))
                            .with_context(|| {
                                format!(
                                    "rank {} recv from {src} tag {tag}: deadline \
                                     {:?} exceeded",
                                    self.rank, self.recv_deadline
                                )
                            });
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("rank {}: all peers hung up", self.rank));
                }
            }
        }
    }

    /// Number of parked out-of-order messages (tests assert this drains to
    /// zero so the pending map cannot leak across a long run).
    pub fn pending_messages(&self) -> usize {
        self.pending.values().map(|q| q.len()).sum()
    }

    /// Receive and require an f32 payload (wire-format mismatch is a bug).
    pub fn recv_f32(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        match self.recv(src, tag)? {
            Payload::F32(v) => Ok(v),
            Payload::F16(_) => Err(anyhow!(
                "rank {}: expected f32 wire payload from {src} tag {tag}, got f16",
                self.rank
            )),
        }
    }

    /// Receive and require an f16 payload.
    pub fn recv_f16(&mut self, src: usize, tag: u64) -> Result<Vec<u16>> {
        match self.recv(src, tag)? {
            Payload::F16(v) => Ok(v),
            Payload::F32(_) => Err(anyhow!(
                "rank {}: expected f16 wire payload from {src} tag {tag}, got f32",
                self.rank
            )),
        }
    }
}

impl std::fmt::Debug for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Endpoint")
            .field("rank", &self.rank)
            .field("n", &self.n)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_round_trip() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 7, &[1.0, 2.0, 3.0]).unwrap();
        let got = b.recv_f32(0, 7).unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 1, &[1.0]).unwrap();
        a.send_f32(1, 2, &[2.0]).unwrap();
        a.send_f32(1, 1, &[3.0]).unwrap();
        // Receive tag 2 first; tag-1 messages must stay queued in order.
        assert_eq!(b.recv_f32(0, 2).unwrap(), vec![2.0]);
        assert_eq!(b.recv_f32(0, 1).unwrap(), vec![1.0]);
        assert_eq!(b.recv_f32(0, 1).unwrap(), vec![3.0]);
    }

    #[test]
    fn byte_conservation_across_threads() {
        let n = 4;
        let eps = Mesh::new(n);
        let counters = eps[0].counters.clone();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let me = ep.rank();
                    let right = (me + 1) % 4;
                    let left = (me + 3) % 4;
                    for step in 0..10u64 {
                        ep.send_f32(right, step, &vec![me as f32; 100]).unwrap();
                        let got = ep.recv_f32(left, step).unwrap();
                        assert_eq!(got, vec![left as f32; 100]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (sent, recvd, msgs) = counters.snapshot();
        assert_eq!(sent, recvd);
        assert_eq!(sent, 4 * 10 * 100 * 4); // ranks * steps * elems * 4B
        assert_eq!(msgs, 40);
    }

    #[test]
    fn pending_queue_drains_and_entries_are_dropped() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // out-of-order burst: many messages on tags received later
        for i in 0..50u64 {
            a.send_f32(1, i % 5, &[i as f32]).unwrap();
        }
        a.send_f32(1, 99, &[99.0]).unwrap();
        // receiving tag 99 first parks all 50 burst messages
        assert_eq!(b.recv_f32(0, 99).unwrap(), vec![99.0]);
        assert_eq!(b.pending_messages(), 50);
        // drain them in FIFO order per tag
        for i in 0..50u64 {
            let tag = i % 5;
            let got = b.recv_f32(0, tag).unwrap();
            // per-tag order: the k-th receive of `tag` is message 5k+tag
            assert_eq!(got, vec![(5 * (i / 5) + tag) as f32], "tag {tag}");
        }
        // fully drained: no empty queues linger in the map
        assert_eq!(b.pending_messages(), 0);
        assert!(b.pending.is_empty(), "empty pending entries leaked");
    }

    #[test]
    fn f16_payload_counts_two_bytes() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        a.send_f16(1, 0, vec![0x3C00; 8]).unwrap();
        let got = b.recv_f16(0, 0).unwrap();
        assert_eq!(got.len(), 8);
        let (sent, _, _) = a.counters().snapshot();
        assert_eq!(sent, 16);
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 0, &[1.0]).unwrap();
        assert!(b.recv_f16(0, 0).is_err());
    }

    #[test]
    fn send_out_of_range_is_error() {
        let mut eps = Mesh::new(2);
        assert!(eps[0].send_f32(5, 0, &[1.0]).is_err());
    }

    /// The freelist must never hand back a stale payload: a recycled long
    /// buffer reused for a shorter message carries exactly the new bytes —
    /// no leftover tail, no leftover length.
    #[test]
    fn freelist_never_hands_back_stale_payloads() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();

        // f32: long payload recycled on b, then b sends a short one.
        a.send_f32(1, 0, &[9.0; 64]).unwrap();
        let long = b.recv_f32(0, 0).unwrap();
        assert_eq!(long.len(), 64);
        b.recycle_f32(long);
        b.send_f32(0, 1, &[1.0, 2.0]).unwrap();
        assert!(b.freelist_hits() >= 1, "short send must hit the freelist");
        assert_eq!(a.recv_f32(1, 1).unwrap(), vec![1.0, 2.0]);

        // f16: alloc after recycling a longer buffer is exact-length and
        // zero-filled, not a truncated view of the old contents.
        a.send_f16(1, 2, vec![7u16; 50]).unwrap();
        let enc = b.recv_f16(0, 2).unwrap();
        b.recycle_f16(enc);
        let mut short = b.alloc_f16(3);
        assert_eq!(short, vec![0u16; 3]);
        short.copy_from_slice(&[1, 2, 3]);
        b.send_f16(0, 3, short).unwrap();
        assert_eq!(a.recv_f16(1, 3).unwrap(), vec![1, 2, 3]);

        // the cap bounds parked buffers
        for _ in 0..100 {
            b.recycle_f32(vec![0.0; 4]);
        }
        assert!(b.free_f32.len() <= FREELIST_CAP);
    }

    /// The core deadlock fix: a recv blocked on a peer unwinds with
    /// `PeerDead` as soon as that peer is marked dead — no message needed.
    #[test]
    fn recv_unblocks_when_peer_is_marked_dead() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t0 = Instant::now();
        let killer = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            a.mark_dead(0);
        });
        let err = b.recv_f32(0, 0).unwrap_err();
        killer.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "recv did not unblock fast");
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 0 })
        );
    }

    /// An abort triggered by *any* death unwinds recvs waiting on healthy
    /// peers too (victim ranks see `Aborted`, not `PeerDead`).
    #[test]
    fn abort_unblocks_recv_from_healthy_peer() {
        let eps = Mesh::new(3);
        let health = eps[0].health_arc();
        let mut ep2 = eps.into_iter().nth(2).unwrap();
        health.mark_dead(1);
        // rank 2 waits on rank 0 (healthy) — must still unwind via abort
        let err = ep2.recv_f32(0, 0).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::Aborted { origin: 1 })
        );
        assert_eq!(health.first_dead(), Some(1));
        assert_eq!(health.dead_ranks(), vec![1]);
    }

    #[test]
    fn send_to_dead_rank_fails_fast() {
        let eps = Mesh::new(2);
        eps[0].mark_dead(1);
        let err = eps[0].send_f16(1, 0, vec![1]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 1 })
        );
    }

    /// The recv deadline is the belt-and-braces bound: with no one marking
    /// anyone dead, an absent message still surfaces as `PeerDead` (and
    /// marks the silent peer dead for the rest of the mesh).
    #[test]
    fn recv_deadline_marks_silent_peer_dead() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        b.set_recv_deadline(Some(Duration::from_millis(30)));
        let t0 = Instant::now();
        let err = b.recv_f32(0, 7).unwrap_err();
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 0 })
        );
        assert!(b.health().is_dead(0));
        assert!(b.health().aborted());
    }

    /// Heartbeats: blocked receivers keep beating; a completed rank marks
    /// itself done so a monitor can tell "finished" from "hung".
    #[test]
    fn heartbeats_tick_while_blocked_and_done_is_sticky() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let health = a.health_arc();
        let waiter = thread::spawn(move || {
            let _ = b.recv_f32(0, 0); // unblocked by the abort below
        });
        thread::sleep(Duration::from_millis(50));
        // rank 1 is blocked in recv, but its recv loop keeps it beating
        assert!(
            health.millis_since_beat(1) < 40,
            "blocked recv must keep beating ({}ms stale)",
            health.millis_since_beat(1)
        );
        health.mark_done(0);
        assert!(health.is_done(0));
        health.mark_dead(0);
        waiter.join().unwrap();
    }
}
