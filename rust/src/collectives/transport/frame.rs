//! Length-prefixed wire codec shared by every socket-backed channel (the
//! TCP data mesh *and* the coordinator/worker control plane).
//!
//! Frame layout, all integers little-endian:
//!
//! ```text
//! [u32 len][u8 kind][u32 src][u32 dst][u64 tag][len-17 body bytes]
//! ```
//!
//! `len` counts everything after the length word (the 17 fixed header
//! bytes plus the body), so a reader always knows exactly how much to
//! pull off the socket — no sentinels, no scanning. Kinds:
//!
//! * [`KIND_F32`] / [`KIND_F16`] — collective payloads; the body is the
//!   packed little-endian element array and `(src, dst, tag)` carry the
//!   mesh addressing, so a data frame is exactly one in-memory
//!   [`Payload`] message on the wire.
//! * [`KIND_CONTROL`] — a UTF-8 JSON object (coordinator/worker protocol,
//!   the mesh `bye` handshake).
//! * [`KIND_BLOB`] — raw bytes (checkpoint-encoded worker state).
//!
//! The FP16↔FP32 **wire conversion** lives here too ([`encode_f16`] /
//! [`decode_f16`] / [`accumulate_f16`]): the schedules and the codec share
//! one quantisation path, so an FP16 hop is bit-identical whichever
//! transport carries it.

use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, Context as _, Result};

use super::{MeshError, Payload};
use crate::util::half;

/// Body is a packed `[f32]` array (4 bytes/elem, little-endian).
pub const KIND_F32: u8 = 0;
/// Body is a packed `[u16]` binary16 array (2 bytes/elem, little-endian).
pub const KIND_F16: u8 = 1;
/// Body is a UTF-8 JSON object (control plane).
pub const KIND_CONTROL: u8 = 2;
/// Body is raw bytes (state transfer).
pub const KIND_BLOB: u8 = 3;

/// Fixed header bytes covered by the length word: kind + src + dst + tag.
pub const HEADER_BYTES: usize = 1 + 4 + 4 + 8;

/// Default cap on one frame's `len` field — a corrupt or hostile length
/// word must not translate into an unbounded allocation. 64 MiB clears a
/// full ResNet-50 FP32 gradient (~102 MB) only when bucketed, which is
/// how the pipeline ships it anyway; `[transport] max_frame_bytes` tunes
/// this per deployment.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 64 << 20;

/// Everything a frame says except its body (which the reader leaves in
/// the caller's scratch buffer to keep per-frame allocations at zero).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: u8,
    pub src: u32,
    pub dst: u32,
    pub tag: u64,
}

/// Serialize one frame into `buf` (cleared first). The buffer is meant to
/// be reused across sends, so steady-state framing allocates nothing.
pub fn encode_frame(buf: &mut Vec<u8>, kind: u8, src: u32, dst: u32, tag: u64, body: &[u8]) {
    buf.clear();
    buf.reserve(4 + HEADER_BYTES + body.len());
    let len = (HEADER_BYTES + body.len()) as u32;
    buf.extend_from_slice(&len.to_le_bytes());
    buf.push(kind);
    buf.extend_from_slice(&src.to_le_bytes());
    buf.extend_from_slice(&dst.to_le_bytes());
    buf.extend_from_slice(&tag.to_le_bytes());
    buf.extend_from_slice(body);
}

/// Serialize a collective payload frame into `buf` (cleared first),
/// packing the elements little-endian without an intermediate body copy.
pub fn encode_payload_frame(buf: &mut Vec<u8>, src: u32, dst: u32, tag: u64, p: &Payload) {
    let (kind, body_len) = match p {
        Payload::F32(v) => (KIND_F32, 4 * v.len()),
        Payload::F16(v) => (KIND_F16, 2 * v.len()),
    };
    buf.clear();
    buf.reserve(4 + HEADER_BYTES + body_len);
    let len = (HEADER_BYTES + body_len) as u32;
    buf.extend_from_slice(&len.to_le_bytes());
    buf.push(kind);
    buf.extend_from_slice(&src.to_le_bytes());
    buf.extend_from_slice(&dst.to_le_bytes());
    buf.extend_from_slice(&tag.to_le_bytes());
    match p {
        Payload::F32(v) => {
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Payload::F16(v) => {
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Decode a payload body (as left in the reader's scratch by
/// [`read_frame`]) back into a [`Payload`]. `out_*` storage may come from
/// a freelist; both paths size it exactly, so a reused longer buffer can
/// never leak a stale tail.
pub fn decode_payload(
    kind: u8,
    body: &[u8],
    mut out_f32: Vec<f32>,
    mut out_f16: Vec<u16>,
) -> Result<Payload> {
    match kind {
        KIND_F32 => {
            if body.len() % 4 != 0 {
                bail!("f32 frame body of {} bytes is not 4-aligned", body.len());
            }
            out_f32.clear();
            out_f32.reserve(body.len() / 4);
            for c in body.chunks_exact(4) {
                out_f32.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            Ok(Payload::F32(out_f32))
        }
        KIND_F16 => {
            if body.len() % 2 != 0 {
                bail!("f16 frame body of {} bytes is not 2-aligned", body.len());
            }
            out_f16.clear();
            out_f16.reserve(body.len() / 2);
            for c in body.chunks_exact(2) {
                out_f16.push(u16::from_le_bytes([c[0], c[1]]));
            }
            Ok(Payload::F16(out_f16))
        }
        other => bail!("frame kind {other} is not a payload kind"),
    }
}

/// Write one already-encoded frame (see [`encode_frame`]) to the socket.
pub fn write_frame(w: &mut impl Write, encoded: &[u8]) -> Result<()> {
    w.write_all(encoded)?;
    Ok(())
}

/// Convenience: encode a control-plane JSON frame and write it.
pub fn write_control(w: &mut impl Write, buf: &mut Vec<u8>, json: &str) -> Result<()> {
    encode_frame(buf, KIND_CONTROL, 0, 0, 0, json.as_bytes());
    write_frame(w, buf)
}

/// Convenience: encode a raw-bytes blob frame and write it.
pub fn write_blob(w: &mut impl Write, buf: &mut Vec<u8>, blob: &[u8]) -> Result<()> {
    encode_frame(buf, KIND_BLOB, 0, 0, 0, blob);
    write_frame(w, buf)
}

/// Read one frame. Returns `Ok(None)` on a clean EOF **at a frame
/// boundary** (the peer closed between frames); EOF mid-frame is a typed
/// [`MeshError::Truncated`], and a length word over `max_frame_bytes` a
/// typed [`MeshError::FrameTooLarge`] — rejected *before* any body
/// allocation, so a corrupt or hostile length prefix can neither panic
/// the reader nor balloon memory. The body lands in `body` (cleared
/// first), which the caller reuses across frames.
pub fn read_frame(
    r: &mut impl Read,
    max_frame_bytes: usize,
    body: &mut Vec<u8>,
) -> Result<Option<FrameHeader>> {
    let mut len_word = [0u8; 4];
    match read_exact_or_eof(r, &mut len_word)? {
        ReadOutcome::Eof => return Ok(None),
        ReadOutcome::Full => {}
    }
    let len = u32::from_le_bytes(len_word) as usize;
    if len < HEADER_BYTES {
        // A frame that cannot even hold its own header is a truncation at
        // the source, whatever produced it.
        return Err(anyhow::Error::new(MeshError::Truncated { got: len, want: HEADER_BYTES }))
            .with_context(|| {
                format!("frame length {len} shorter than the {HEADER_BYTES}-byte header")
            });
    }
    if len > max_frame_bytes {
        return Err(anyhow::Error::new(MeshError::FrameTooLarge { len, max: max_frame_bytes }))
            .with_context(|| {
                format!("frame length {len} exceeds max_frame_bytes {max_frame_bytes}")
            });
    }
    let mut header = [0u8; HEADER_BYTES];
    read_exact_typed(r, &mut header, 0, len)?;
    let kind = header[0];
    let src = u32::from_le_bytes([header[1], header[2], header[3], header[4]]);
    let dst = u32::from_le_bytes([header[5], header[6], header[7], header[8]]);
    let tag = u64::from_le_bytes([
        header[9], header[10], header[11], header[12], header[13], header[14], header[15],
        header[16],
    ]);
    body.clear();
    body.resize(len - HEADER_BYTES, 0);
    read_exact_typed(r, body, HEADER_BYTES, len)?;
    Ok(Some(FrameHeader { kind, src, dst, tag }))
}

enum ReadOutcome {
    Full,
    Eof,
}

/// `read_exact`, except a clean EOF *before the first byte* is reported
/// as [`ReadOutcome::Eof`] instead of an error — that is how a peer
/// signals it has no more frames. EOF after the first byte is a typed
/// [`MeshError::Truncated`] (a partial length word is already mid-frame).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<ReadOutcome> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(ReadOutcome::Eof);
                }
                return Err(anyhow::Error::new(MeshError::Truncated {
                    got: filled,
                    want: buf.len(),
                }))
                .context("stream truncated inside the frame length word");
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadOutcome::Full)
}

/// `read_exact` for a region *inside* a frame whose declared post-length
/// size is `want`: any EOF is a typed [`MeshError::Truncated`] reporting
/// how much of the frame actually arrived (`got_before` + what this call
/// managed to read).
fn read_exact_typed(
    r: &mut impl Read,
    buf: &mut [u8],
    got_before: usize,
    want: usize,
) -> Result<()> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(anyhow::Error::new(MeshError::Truncated {
                    got: got_before + filled,
                    want,
                }))
                .context("stream truncated mid-frame");
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// FP16 ↔ FP32 wire conversion — the codec the schedules quantise through.
// ---------------------------------------------------------------------------

/// Quantise `src` to binary16 into `out` (resized to match). This is the
/// send-side half of the FP16 wire; pair with [`decode_f16`] /
/// [`accumulate_f16`] on the receive side.
pub fn encode_f16(src: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.resize(src.len(), 0);
    half::encode_slice(src, out);
}

/// Widen binary16 `src` into `out` (resized to match).
pub fn decode_f16(src: &[u16], out: &mut Vec<f32>) {
    out.clear();
    out.resize(src.len(), 0.0);
    half::decode_slice(src, out);
}

/// Reduce-scatter hop: widen each binary16 element of `src`, add it into
/// `acc`, and requantise the sum in place — fused, no intermediate
/// buffer, same numerics as an FP16 NCCL ring.
pub fn accumulate_f16(acc: &mut [f32], src: &[u16]) {
    half::accumulate_quantized(acc, src);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::prop_seeded;

    fn round_trip(p: &Payload) -> (FrameHeader, Payload) {
        let mut buf = Vec::new();
        encode_payload_frame(&mut buf, 3, 5, 42, p);
        let mut cursor = &buf[..];
        let mut body = Vec::new();
        let h = read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES, &mut body)
            .unwrap()
            .expect("one frame");
        assert!(cursor.is_empty(), "frame must consume exactly its bytes");
        let p = decode_payload(h.kind, &body, Vec::new(), Vec::new()).unwrap();
        (h, p)
    }

    #[test]
    fn header_fields_survive_the_wire() {
        let (h, _) = round_trip(&Payload::F32(vec![1.0, -2.5]));
        assert_eq!(
            h,
            FrameHeader { kind: KIND_F32, src: 3, dst: 5, tag: 42 }
        );
        let (h, _) = round_trip(&Payload::F16(vec![0x3C00]));
        assert_eq!(h.kind, KIND_F16);
    }

    #[test]
    fn empty_payloads_frame_cleanly() {
        let (h, p) = round_trip(&Payload::F32(vec![]));
        assert_eq!(h.kind, KIND_F32);
        assert!(p.is_empty());
    }

    #[test]
    fn eof_between_frames_is_none_mid_frame_is_error() {
        let mut buf = Vec::new();
        encode_payload_frame(&mut buf, 0, 1, 7, &Payload::F32(vec![1.0, 2.0]));
        // clean EOF at offset 0
        let mut empty: &[u8] = &[];
        let mut body = Vec::new();
        assert!(read_frame(&mut empty, 1 << 20, &mut body).unwrap().is_none());
        // every proper prefix of a frame is a truncation error
        for cut in 1..buf.len() {
            let mut partial = &buf[..cut];
            assert!(
                read_frame(&mut partial, 1 << 20, &mut body).is_err(),
                "cut at {cut} must be a truncation error"
            );
        }
        // two frames back to back parse independently
        let mut two = buf.clone();
        let mut second = Vec::new();
        encode_payload_frame(&mut second, 1, 0, 8, &Payload::F16(vec![9, 10]));
        two.extend_from_slice(&second);
        let mut cursor = &two[..];
        let a = read_frame(&mut cursor, 1 << 20, &mut body).unwrap().unwrap();
        assert_eq!((a.kind, a.tag), (KIND_F32, 7));
        let b = read_frame(&mut cursor, 1 << 20, &mut body).unwrap().unwrap();
        assert_eq!((b.kind, b.tag), (KIND_F16, 8));
        assert!(read_frame(&mut cursor, 1 << 20, &mut body).unwrap().is_none());
    }

    #[test]
    fn oversized_and_undersized_lengths_are_rejected() {
        // len word below the header size
        let mut bad = 5u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[0u8; 32]);
        let mut body = Vec::new();
        assert!(read_frame(&mut &bad[..], 1 << 20, &mut body).is_err());
        // len word above the cap
        let mut huge = (1u32 << 30).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 32]);
        assert!(read_frame(&mut &huge[..], 1 << 20, &mut body).is_err());
    }

    /// The codec-hardening satellite: malformed input — an oversized
    /// length prefix, an impossibly short one, and truncation at every
    /// mid-prefix byte offset — must surface as *typed* [`MeshError`]s
    /// (downcastable through the context chain), never a panic, and the
    /// oversized case must be rejected before any body allocation.
    #[test]
    fn malformed_frames_surface_typed_mesh_errors() {
        let mut body = Vec::new();

        // length word over the cap: FrameTooLarge, body buffer untouched
        let mut huge = (1u32 << 30).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 64]);
        let err = read_frame(&mut &huge[..], 1 << 20, &mut body).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::FrameTooLarge { len: 1 << 30, max: 1 << 20 })
        );
        assert!(format!("{err:#}").contains("max_frame_bytes"));
        assert_eq!(body.capacity(), 0, "oversized frame must be rejected before allocating");

        // length word below the header size: a truncation at the source
        let mut tiny = 5u32.to_le_bytes().to_vec();
        tiny.extend_from_slice(&[0u8; 32]);
        let err = read_frame(&mut &tiny[..], 1 << 20, &mut body).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::Truncated { got: 5, want: HEADER_BYTES })
        );

        // every proper prefix of a real frame: Truncated with got < want
        let mut frame = Vec::new();
        encode_payload_frame(&mut frame, 0, 1, 7, &Payload::F32(vec![1.0, 2.0, 3.0]));
        for cut in 1..frame.len() {
            let err = read_frame(&mut &frame[..cut], 1 << 20, &mut body).unwrap_err();
            match err.downcast_ref::<MeshError>() {
                Some(&MeshError::Truncated { got, want }) => {
                    assert!(got < want, "cut {cut}: got {got} !< want {want}")
                }
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn control_frames_round_trip_json() {
        let mut buf = Vec::new();
        let mut out: Vec<u8> = Vec::new();
        write_control(&mut out, &mut buf, r#"{"type":"hello","rank":3}"#).unwrap();
        let mut body = Vec::new();
        let h = read_frame(&mut &out[..], 1 << 20, &mut body).unwrap().unwrap();
        assert_eq!(h.kind, KIND_CONTROL);
        let j = crate::util::json::Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.get("type").unwrap().as_str().unwrap(), "hello");
        assert_eq!(j.get("rank").unwrap().as_usize().unwrap(), 3);
    }

    /// The satellite property test: random tensors, both wire widths,
    /// through encode → frame → decode, with the *same* scratch buffers
    /// reused across frames — a stale byte from an earlier (longer)
    /// payload must never survive into a later one.
    #[test]
    fn property_payload_round_trip_reuses_buffers_without_stale_leaks() {
        let mut wire = Vec::new(); // frame bytes, reused
        let mut body = Vec::new(); // reader scratch, reused
        let mut scratch = super::super::Scratch::default();
        let mut f16_scratch: Vec<u16> = Vec::new();
        prop_seeded(0xF2A3_E7E1, 200, |g| {
            let n = g.usize_in(0..=300);
            let vals = g.vec_normal(n);
            if g.bool() {
                // FP32 path: bytes must survive bit-exactly.
                let p = Payload::F32(vals.clone());
                encode_payload_frame(&mut wire, 1, 2, g.u64() % 1000, &p);
                let h = read_frame(&mut &wire[..], DEFAULT_MAX_FRAME_BYTES, &mut body)
                    .unwrap()
                    .unwrap();
                // decode into freelist storage recycled from earlier cases
                let out = scratch.alloc_f32(0);
                let got = decode_payload(h.kind, &body, out, Vec::new()).unwrap();
                match got {
                    Payload::F32(v) => {
                        assert_eq!(v.len(), n, "length leak from a previous frame");
                        for (a, b) in v.iter().zip(&vals) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                        scratch.recycle_f32(v);
                    }
                    _ => panic!("kind flipped"),
                }
            } else {
                // FP16 path: quantise → frame → decode must agree with
                // quantising directly, element for element.
                encode_f16(&vals, &mut f16_scratch);
                let p = Payload::F16(f16_scratch.clone());
                encode_payload_frame(&mut wire, 2, 1, g.u64() % 1000, &p);
                let h = read_frame(&mut &wire[..], DEFAULT_MAX_FRAME_BYTES, &mut body)
                    .unwrap()
                    .unwrap();
                let out = scratch.alloc_f16(0);
                let got = decode_payload(h.kind, &body, Vec::new(), out).unwrap();
                match got {
                    Payload::F16(enc) => {
                        assert_eq!(enc.len(), n, "length leak from a previous frame");
                        assert_eq!(enc, f16_scratch, "f16 bits changed on the wire");
                        let mut wide = scratch.alloc_f32(n);
                        decode_f16(&enc, &mut wide);
                        for (w, v) in wide.iter().zip(&vals) {
                            assert_eq!(
                                w.to_bits(),
                                half::quantize_f16(*v).to_bits(),
                                "framed f16 decode must equal direct quantisation"
                            );
                        }
                        scratch.recycle_f32(wide);
                        scratch.recycle_f16(enc);
                    }
                    _ => panic!("kind flipped"),
                }
            }
        });
        assert!(scratch.hits() > 0, "the property must exercise buffer reuse");
    }

    /// `accumulate_f16` through the codec matches decode-then-add-then-
    /// requantise done by hand (the fused hop is a pure refactor of the
    /// unfused one).
    #[test]
    fn property_accumulate_matches_unfused_path() {
        prop_seeded(0xACC0_F16A, 100, |g| {
            let n = g.usize_in(1..=64);
            let base = g.vec_normal(n);
            let add = g.vec_normal(n);
            let mut enc = Vec::new();
            encode_f16(&add, &mut enc);

            let mut fused = base.clone();
            accumulate_f16(&mut fused, &enc);

            let mut wide = Vec::new();
            decode_f16(&enc, &mut wide);
            for (f, (b, w)) in fused.iter().zip(base.iter().zip(&wide)) {
                assert_eq!(f.to_bits(), half::quantize_f16(b + w).to_bits());
            }
        });
    }
}
