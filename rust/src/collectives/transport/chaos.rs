//! Deterministic network-chaos harness: a [`ChaosTransport`] wrapper that
//! injects per-link frame delay, loss-as-latency, duplication and
//! reordering into **any** inner [`Transport`] — seeded, so every injected
//! event is a pure function of `(seed, src, dst, tag)` and a test can
//! assert exactly what happened.
//!
//! Design constraints that shape the implementation:
//!
//! * **Bit-identical results.** Collectives match messages on `(src, tag)`
//!   and fix the reduction order, so delay and reordering are absorbed by
//!   the pending map without changing a single ULP. Loss is presented as
//!   latency (the frame is sent after a penalty sleep — the model of a
//!   reliable link retransmitting), never as silent data loss.
//! * **Duplicates must not poison later traffic.** Collectives *reuse*
//!   tags step after step, so a stray duplicate parked in the pending map
//!   would be consumed by the *next* step's receive of the same
//!   `(src, tag)` — corrupting it. The receiver therefore recomputes the
//!   sender's (deterministic) duplication decision and explicitly consumes
//!   and recycles the extra copy at the matching `recv`.
//! * **Reordering must not deadlock.** A reorder holds one outgoing frame
//!   and releases it *behind* the next send on any link; held frames are
//!   force-flushed before every receive and on drop, so a schedule that
//!   stops sending still makes progress.
//! * **Zero overhead when disabled.** The wrapper is only installed when
//!   `[fault.chaos] enabled = true`; the disabled path is the unwrapped
//!   transport, byte for byte.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::{mix64, Counters, Health, Payload, Scratch, Transport};

/// `[fault.chaos]` — seeded fault-injection probabilities, all applied
/// per *frame* on each `src → dst` send (self-edges are exempt: there is
/// no wire under them).
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    pub enabled: bool,
    /// Root seed; every injected event derives from it deterministically.
    pub seed: u64,
    /// Probability a frame is delayed before sending.
    pub delay_prob: f64,
    /// Upper bound on one injected delay, microseconds (the actual delay
    /// is hash-derived in `1..=delay_us_max`).
    pub delay_us_max: u64,
    /// Probability a frame is "dropped" — charged the retransmit penalty
    /// below, then sent (reliable-link loss model).
    pub drop_prob: f64,
    /// Retransmit penalty per dropped frame, microseconds.
    pub drop_delay_us: u64,
    /// Probability a frame is sent twice (the receiver consumes the
    /// duplicate deterministically).
    pub dup_prob: f64,
    /// Probability a frame is held and released behind the next send.
    pub reorder_prob: f64,
    /// Probability a *rank* is a chronic straggler: every injected delay
    /// on frames it sends is stretched by `slow_factor`. Decided once per
    /// rank as a pure function of `(seed, rank)` — a heterogeneous-cluster
    /// model, not per-frame noise.
    pub slow_prob: f64,
    /// Delay stretch applied to a slow rank's injected delays (≥ 1).
    pub slow_factor: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 0x5EED,
            delay_prob: 0.0,
            delay_us_max: 500,
            drop_prob: 0.0,
            drop_delay_us: 2000,
            dup_prob: 0.0,
            reorder_prob: 0.0,
            slow_prob: 0.0,
            slow_factor: 4.0,
        }
    }
}

/// The injection decisions for one `(src, dst, tag)` frame. Both ends of a
/// link can compute this independently and agree — that is what lets the
/// receiver absorb duplicates without any side-channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkPlan {
    /// Injected pre-send delay in microseconds (0 = none).
    pub delay_us: u64,
    pub drop: bool,
    pub dup: bool,
    pub reorder: bool,
}

/// Map a hash to a uniform float in `[0, 1)`.
pub(crate) fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl ChaosConfig {
    /// The deterministic injection plan for one frame. Pure: same inputs,
    /// same plan, on every rank that computes it.
    pub fn plan(&self, src: usize, dst: usize, tag: u64) -> LinkPlan {
        if !self.enabled || src == dst {
            return LinkPlan { delay_us: 0, drop: false, dup: false, reorder: false };
        }
        let key = mix64(
            self.seed ^ mix64(((src as u64) << 32) | dst as u64) ^ mix64(tag ^ 0xC4A0_5EED),
        );
        let delay = unit(mix64(key ^ 1)) < self.delay_prob;
        let delay_us = if delay && self.delay_us_max > 0 {
            1 + mix64(key ^ 2) % self.delay_us_max
        } else {
            0
        };
        LinkPlan {
            delay_us,
            drop: unit(mix64(key ^ 3)) < self.drop_prob,
            dup: unit(mix64(key ^ 4)) < self.dup_prob,
            reorder: unit(mix64(key ^ 5)) < self.reorder_prob,
        }
    }

    /// Per-rank slowdown multiplier for injected delays: `slow_factor` when
    /// the seed elects `rank` a straggler, else 1. Pure function of
    /// `(seed, rank)` — every endpoint of a mesh agrees on who is slow, and
    /// the same seed always elects the same ranks.
    pub fn rank_slow_multiplier(&self, rank: usize) -> f64 {
        if !self.enabled || self.slow_prob <= 0.0 {
            return 1.0;
        }
        let key = mix64(self.seed ^ mix64(rank as u64 ^ 0x5106_C0DE));
        if unit(key) < self.slow_prob {
            self.slow_factor.max(1.0)
        } else {
            1.0
        }
    }
}

/// Shared tallies of every event the harness injected — one block per
/// wrapped mesh, so a test can assert the seed's exact schedule fired.
#[derive(Debug, Default)]
pub struct ChaosCounters {
    pub delays: AtomicU64,
    pub drops: AtomicU64,
    pub dups: AtomicU64,
    pub reorders: AtomicU64,
}

impl ChaosCounters {
    /// `(delays, drops, dups, reorders)` injected so far.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.delays.load(Ordering::Relaxed),
            self.drops.load(Ordering::Relaxed),
            self.dups.load(Ordering::Relaxed),
            self.reorders.load(Ordering::Relaxed),
        )
    }

    /// Total injected events of any kind.
    pub fn total(&self) -> u64 {
        let (a, b, c, d) = self.snapshot();
        a + b + c + d
    }
}

/// A [`Transport`] that injects the seeded chaos schedule around an inner
/// transport. Wrap every endpoint of a mesh with the *same* config and a
/// shared counter block; unwrapped and wrapped meshes are interchangeable
/// under every collective.
pub struct ChaosTransport<T: Transport> {
    inner: T,
    cfg: ChaosConfig,
    counters: Arc<ChaosCounters>,
    /// At most one reordered frame in flight per endpoint.
    held: Option<(usize, u64, Payload)>,
}

impl<T: Transport> ChaosTransport<T> {
    pub fn new(inner: T, cfg: ChaosConfig, counters: Arc<ChaosCounters>) -> Self {
        Self { inner, cfg, counters, held: None }
    }

    /// Wrap a whole mesh's endpoints under one config + one shared counter
    /// block.
    pub fn wrap_all(eps: Vec<T>, cfg: &ChaosConfig) -> (Vec<ChaosTransport<T>>, Arc<ChaosCounters>) {
        let counters = Arc::new(ChaosCounters::default());
        let wrapped = eps
            .into_iter()
            .map(|ep| ChaosTransport::new(ep, cfg.clone(), counters.clone()))
            .collect();
        (wrapped, counters)
    }

    /// The shared injection tallies of this endpoint's mesh.
    pub fn chaos_counters(&self) -> Arc<ChaosCounters> {
        self.counters.clone()
    }

    fn raw_send(&mut self, dst: usize, tag: u64, payload: Payload, dup: bool) -> Result<()> {
        if dup {
            self.counters.dups.fetch_add(1, Ordering::Relaxed);
            let copy = payload.clone();
            self.inner.send(dst, tag, payload)?;
            self.inner.send(dst, tag, copy)
        } else {
            self.inner.send(dst, tag, payload)
        }
    }

    /// Release the held (reordered) frame, if any. Called behind every
    /// later send, before every receive, and on drop — a held frame can
    /// outlive at most one send gap, never the endpoint.
    fn flush_held(&mut self) -> Result<()> {
        if let Some((dst, tag, payload)) = self.held.take() {
            let dup = self.cfg.plan(self.inner.rank(), dst, tag).dup;
            self.raw_send(dst, tag, payload, dup)?;
        }
        Ok(())
    }
}

impl<T: Transport> Drop for ChaosTransport<T> {
    fn drop(&mut self) {
        // Best effort: a send failure while unwinding must not double-panic.
        let _ = self.flush_held();
    }
}

impl<T: Transport> Transport for ChaosTransport<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world_size(&self) -> usize {
        self.inner.world_size()
    }

    fn counters(&self) -> &Counters {
        self.inner.counters()
    }

    fn counters_arc(&self) -> Arc<Counters> {
        self.inner.counters_arc()
    }

    fn health(&self) -> &Health {
        self.inner.health()
    }

    fn health_arc(&self) -> Arc<Health> {
        self.inner.health_arc()
    }

    fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.inner.set_recv_deadline(d)
    }

    fn send(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        let plan = self.cfg.plan(self.inner.rank(), dst, tag);
        // Heterogeneity model: a seed-elected slow rank pays a stretched
        // version of every injected delay on its outgoing edges.
        let slow = self.cfg.rank_slow_multiplier(self.inner.rank());
        if plan.delay_us > 0 {
            self.counters.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(
                (plan.delay_us as f64 * slow) as u64,
            ));
        }
        if plan.drop {
            // Loss on a reliable link = a retransmit penalty, then delivery.
            self.counters.drops.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(
                (self.cfg.drop_delay_us as f64 * slow) as u64,
            ));
        }
        if plan.reorder && self.held.is_none() {
            self.counters.reorders.fetch_add(1, Ordering::Relaxed);
            self.held = Some((dst, tag, payload));
            return Ok(());
        }
        self.raw_send(dst, tag, payload, plan.dup)?;
        self.flush_held()
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        // Progress guarantee: nothing may stay held while this rank blocks.
        self.flush_held()?;
        let payload = self.inner.recv(src, tag)?;
        // Mirror the sender's duplication decision and absorb the extra
        // copy now — parked in pending, it would corrupt the next step's
        // reuse of this same (src, tag).
        if self.cfg.plan(src, self.inner.rank(), tag).dup {
            let dup = self.inner.recv(src, tag)?;
            self.inner.recycle(dup);
        }
        Ok(payload)
    }

    fn pending_messages(&self) -> usize {
        self.inner.pending_messages()
    }

    fn scratch(&self) -> &Scratch {
        self.inner.scratch()
    }

    fn scratch_mut(&mut self) -> &mut Scratch {
        self.inner.scratch_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::super::Mesh;
    use super::*;
    use std::thread;

    fn noisy(seed: u64) -> ChaosConfig {
        ChaosConfig {
            enabled: true,
            seed,
            delay_prob: 0.3,
            delay_us_max: 50,
            drop_prob: 0.2,
            drop_delay_us: 100,
            dup_prob: 0.2,
            reorder_prob: 0.3,
            slow_prob: 0.0,
            slow_factor: 4.0,
        }
    }

    #[test]
    fn plans_are_deterministic_and_symmetric() {
        let cfg = noisy(42);
        for (src, dst, tag) in [(0usize, 1usize, 0u64), (1, 0, 7), (2, 3, 1 << 40)] {
            let a = cfg.plan(src, dst, tag);
            let b = cfg.plan(src, dst, tag);
            assert_eq!(a, b, "plan must be a pure function");
        }
        // seeds decorrelate the schedule
        let other = noisy(43);
        let differs = (0..64u64).any(|t| cfg.plan(0, 1, t) != other.plan(0, 1, t));
        assert!(differs, "different seeds must give different schedules");
    }

    #[test]
    fn disabled_and_self_edges_inject_nothing() {
        let off = ChaosConfig { enabled: false, ..noisy(1) };
        let on = noisy(1);
        for t in 0..256u64 {
            assert_eq!(
                off.plan(0, 1, t),
                LinkPlan { delay_us: 0, drop: false, dup: false, reorder: false }
            );
            assert_eq!(
                on.plan(2, 2, t),
                LinkPlan { delay_us: 0, drop: false, dup: false, reorder: false }
            );
        }
    }

    /// The straggler election is a pure function of `(seed, rank)`: same
    /// seed ⇒ same slow set on every call; `slow_prob` spans the obvious
    /// extremes; disabled chaos never slows anyone.
    #[test]
    fn rank_slow_multiplier_is_deterministic_per_seed() {
        let mut cfg = noisy(0xBEEF);
        cfg.slow_prob = 0.25;
        cfg.slow_factor = 6.0;
        let first: Vec<f64> = (0..64).map(|r| cfg.rank_slow_multiplier(r)).collect();
        let again: Vec<f64> = (0..64).map(|r| cfg.rank_slow_multiplier(r)).collect();
        assert_eq!(first, again, "election must be pure");
        assert!(first.iter().all(|&m| m == 1.0 || m == 6.0));
        assert!(
            first.iter().any(|&m| m > 1.0),
            "a 25% rate over 64 ranks should elect someone"
        );
        assert!(
            first.iter().any(|&m| m == 1.0),
            "a 25% rate over 64 ranks should spare someone"
        );
        // a different seed elects a different set
        let mut other = noisy(0xBEE0);
        other.slow_prob = 0.25;
        other.slow_factor = 6.0;
        let theirs: Vec<f64> = (0..64).map(|r| other.rank_slow_multiplier(r)).collect();
        assert_ne!(first, theirs, "seeds must decorrelate the slow set");
        // extremes and the disabled path
        cfg.slow_prob = 1.0;
        assert_eq!(cfg.rank_slow_multiplier(3), 6.0);
        cfg.slow_prob = 0.0;
        assert_eq!(cfg.rank_slow_multiplier(3), 1.0);
        let off = ChaosConfig { enabled: false, slow_prob: 1.0, ..noisy(1) };
        assert_eq!(off.rank_slow_multiplier(0), 1.0);
    }

    #[test]
    fn probabilities_roughly_hit_their_rates() {
        let cfg = noisy(0xFEED);
        let n = 4000u64;
        let dups = (0..n).filter(|&t| cfg.plan(0, 1, t).dup).count() as f64 / n as f64;
        let drops = (0..n).filter(|&t| cfg.plan(0, 1, t).drop).count() as f64 / n as f64;
        assert!((dups - 0.2).abs() < 0.05, "dup rate {dups}");
        assert!((drops - 0.2).abs() < 0.05, "drop rate {drops}");
    }

    /// A chaotic in-memory mesh must deliver bit-identical traffic: every
    /// (src, tag) exchange round-trips the exact payload despite dup /
    /// reorder / delay, and the pending maps drain to empty (no poisoned
    /// duplicates left behind for a later tag reuse).
    #[test]
    fn chaotic_exchange_is_lossless_and_leaves_no_residue() {
        let n = 4usize;
        let (eps, counters) = ChaosTransport::wrap_all(Mesh::new(n), &noisy(7));
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let me = ep.rank();
                    // Two "steps" reusing the same tags — the dup-absorb
                    // path is what keeps step 2 clean.
                    for step in 0..2u64 {
                        for peer in 0..n {
                            if peer == me {
                                continue;
                            }
                            let v: Vec<f32> =
                                (0..8).map(|i| (step * 100 + (me * 10 + i) as u64) as f32).collect();
                            ep.send_f32(peer, step, &v).unwrap();
                        }
                        for peer in 0..n {
                            if peer == me {
                                continue;
                            }
                            let got = ep.recv_f32(peer, step).unwrap();
                            let want: Vec<f32> =
                                (0..8).map(|i| (step * 100 + (peer * 10 + i) as u64) as f32).collect();
                            assert_eq!(got, want, "rank {me} from {peer} step {step}");
                        }
                    }
                    assert_eq!(ep.pending_messages(), 0, "rank {me}: residue in pending");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(counters.total() > 0, "a noisy seed must inject something");
    }
}
