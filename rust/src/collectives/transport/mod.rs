//! The transport layer under every collective schedule.
//!
//! This sits where NCCL + MPI sit in the paper's stack, split into three
//! layers so the *schedule* code never sees a concrete channel:
//!
//! * **[`Transport`]** — the trait every collective talks to: tagged
//!   `send`/`recv` of [`Payload`]s between ranks, plus the shared
//!   [`Counters`] / [`Health`] tables and the per-dtype scratch freelists.
//! * **[`mesh`]** — the in-memory implementation ([`Mesh::new(n)`] builds
//!   `n` fully-connected [`Endpoint`]s over condvar-backed inboxes inside
//!   one process). This is the **default** transport and the bit-identical
//!   control for everything the TCP path does.
//! * **[`tcp`]** — the same mesh over `std::net` TCP sockets
//!   ([`TcpMesh::loopback`] for in-process loopback ranks,
//!   [`tcp::connect_mesh`] for real worker processes), speaking the
//!   length-prefixed [`frame`] codec.
//!
//! Messages are matched MPI-style on `(src, tag)`: out-of-order arrivals
//! park in a per-endpoint pending map. Sends never block (in-memory
//! inboxes are unbounded; TCP writes go to the kernel buffer), so ring
//! schedules cannot deadlock on send.
//!
//! Every mesh shares one [`Counters`] block. Tests use it to check
//! *conservation* (total sent == total received), to verify each
//! collective moves exactly the data volume its cost model claims, and —
//! because both transports count the same logical payload bytes — to
//! assert the TCP mesh produces byte-identical traffic to the in-memory
//! control.
//!
//! **Fault path**: every mesh shares one [`Health`] table. A rank (or the
//! coordinator's heartbeat monitor, or a TCP reader seeing its socket
//! drop) can [`Health::mark_dead`] a peer; that raises a mesh-wide abort
//! flag, and every blocked `recv` — which waits on a condvar in bounded
//! slices, never indefinitely — unwinds with a typed [`MeshError`]
//! instead of deadlocking. This is what makes a dead rank mid-collective
//! a recoverable event rather than a process-wide hang.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context as _, Result};

pub mod chaos;
pub mod frame;
pub mod mesh;
pub mod tcp;

pub use chaos::{ChaosConfig, ChaosCounters, ChaosTransport};
pub use mesh::{Endpoint, Mesh};
pub use tcp::{LinkPolicy, TcpEndpoint, TcpMesh, TcpOptions};

/// Typed transport fault. Collectives propagate these through their normal
/// `Result` paths, so a worker can distinguish *being* the failure (a real
/// local error) from being a **victim** of a peer's death / a phase abort
/// (`anyhow`'s `downcast_ref::<MeshError>` finds it through any context
/// chain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshError {
    /// The peer this rank was waiting on (or sending to) is marked dead.
    PeerDead { rank: usize },
    /// The mesh-wide abort flag is up; `origin` is the first rank marked
    /// dead (the death that triggered the abort).
    Aborted { origin: usize },
    /// A frame (outgoing or decoded off the wire) exceeds the configured
    /// `max_frame_bytes` cap. The oversized length is rejected *before*
    /// any allocation, so a corrupt or hostile length prefix can never
    /// balloon memory.
    FrameTooLarge { len: usize, max: usize },
    /// The stream ended (or the declared length was impossibly short)
    /// partway through a frame: `got` of `want` bytes were available.
    Truncated { got: usize, want: usize },
    /// A receiver's inbox hit its high-water cap and refused the message.
    /// Healthy schedules never come near the cap; it exists so a runaway
    /// flood (a chaos dup/reorder storm, a buggy schedule) surfaces as a
    /// typed error instead of unbounded memory growth.
    InboxOverflow { len: usize, cap: usize },
}

impl std::fmt::Display for MeshError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MeshError::PeerDead { rank } => write!(f, "peer rank {rank} is dead"),
            MeshError::Aborted { origin } => {
                write!(f, "collective aborted (first dead rank: {origin})")
            }
            MeshError::FrameTooLarge { len, max } => {
                write!(f, "frame of {len} bytes exceeds max_frame_bytes = {max}")
            }
            MeshError::Truncated { got, want } => {
                write!(f, "truncated frame: got {got} of {want} bytes")
            }
            MeshError::InboxOverflow { len, cap } => {
                write!(f, "inbox overflow: {len} queued messages at cap {cap}")
            }
        }
    }
}

impl std::error::Error for MeshError {}

/// Jittered exponential backoff for dials and reconnects (the `[transport]`
/// `retry_*` keys). Delays grow by 1.5× per attempt from `base` up to
/// `max`, each scaled by a *deterministic* jitter factor in
/// `[1 − jitter, 1 + jitter]` derived from `(salt, attempt)` — so two
/// workers restarted together fan out their dials without the transport
/// depending on ambient randomness, and tests can predict every delay.
#[derive(Debug, Clone, PartialEq)]
pub struct BackoffConfig {
    /// First-retry delay.
    pub base: Duration,
    /// Per-attempt delay ceiling.
    pub max: Duration,
    /// Total attempts before the dial (or reconnect) gives up.
    pub attempts: u32,
    /// Jitter fraction in `[0, 1]`: each delay is scaled by a value in
    /// `[1 − jitter, 1 + jitter]`.
    pub jitter: f64,
}

impl Default for BackoffConfig {
    fn default() -> Self {
        Self {
            base: Duration::from_millis(100),
            max: Duration::from_millis(2000),
            attempts: 16,
            jitter: 0.25,
        }
    }
}

impl BackoffConfig {
    /// The delay to sleep after failed attempt `attempt` (0-based).
    pub fn delay(&self, attempt: u32, salt: u64) -> Duration {
        let nominal = (self.base.as_secs_f64() * 1.5f64.powi(attempt.min(64) as i32))
            .min(self.max.as_secs_f64());
        let h = mix64(salt ^ ((attempt as u64 + 1) << 40) ^ 0x00B0_FF5E_ED00);
        let unit = (h >> 11) as f64 / (1u64 << 53) as f64; // uniform [0, 1)
        let factor = 1.0 + self.jitter * (2.0 * unit - 1.0);
        Duration::from_secs_f64((nominal * factor).max(0.0))
    }

    /// Worst-case total wait across all attempts (every delay at max
    /// jitter) — the deadline a passive accept side should hold out for
    /// while its peer runs this schedule.
    pub fn total_budget(&self) -> Duration {
        let mut total = 0.0f64;
        for a in 0..self.attempts {
            let nominal = (self.base.as_secs_f64() * 1.5f64.powi(a.min(64) as i32))
                .min(self.max.as_secs_f64());
            total += nominal * (1.0 + self.jitter);
        }
        Duration::from_secs_f64(total)
    }
}

/// Splitmix64 finalizer: the one-way avalanche behind every deterministic
/// "random" decision in the transport (backoff jitter, the chaos harness).
/// A pure function of its input — no ambient RNG anywhere on the wire path.
pub(crate) fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Upper bound on one condvar wait in the blocking `recv` loop: how often
/// a receiver that has seen no traffic re-checks the health table (and
/// ticks its own heartbeat). Arrivals interrupt the wait immediately —
/// unlike the old 1 ms sleep-tick poll this burns no CPU while idle — so
/// the slice only bounds *fault* detection latency, not message latency.
const WAIT_SLICE: Duration = Duration::from_millis(20);

/// Shared per-mesh health table: heartbeats, per-rank liveness, and the
/// mesh-wide abort flag. One per mesh; every endpoint holds it, and the
/// coordinator's heartbeat monitor scans it from outside the mesh.
#[derive(Debug)]
pub struct Health {
    start: Instant,
    /// Millis-since-`start` of each rank's last heartbeat.
    beats: Vec<AtomicU64>,
    /// Ranks whose worker thread has exited — cleanly *or* by
    /// erroring/panicking out. They stop beating legitimately; the
    /// heartbeat monitor must not confuse any of them with hung ranks
    /// (whether an exited rank was a casualty is what `dead` records).
    done: Vec<AtomicBool>,
    dead: Vec<AtomicBool>,
    abort: AtomicBool,
    /// First rank marked dead (`usize::MAX` = none yet).
    first_dead: AtomicUsize,
    /// Straggler telemetry: last completed global step + 1 per rank
    /// (0 = none on this mesh yet).
    steps: Vec<AtomicU64>,
    /// EWMA of each rank's per-step **local work** time (compute + apply +
    /// data, communication excluded), in microseconds. In a synchronous
    /// collective every rank's *total* step time converges to the slowest
    /// rank's pace, so only the local-work split identifies the straggler.
    work_ewma_us: Vec<AtomicU64>,
    /// How many steps have fed each rank's EWMA.
    step_samples: Vec<AtomicU64>,
    /// Millis-since-`start` of each rank's last completed step (0 = none).
    progress: Vec<AtomicU64>,
}

impl Health {
    pub(crate) fn new(n: usize) -> Self {
        Self {
            start: Instant::now(),
            beats: (0..n).map(|_| AtomicU64::new(0)).collect(),
            done: (0..n).map(|_| AtomicBool::new(false)).collect(),
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            abort: AtomicBool::new(false),
            first_dead: AtomicUsize::new(usize::MAX),
            steps: (0..n).map(|_| AtomicU64::new(0)).collect(),
            work_ewma_us: (0..n).map(|_| AtomicU64::new(0)).collect(),
            step_samples: (0..n).map(|_| AtomicU64::new(0)).collect(),
            progress: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.dead.len()
    }

    /// Record a liveness tick for `rank`.
    pub fn beat(&self, rank: usize) {
        let ms = self.start.elapsed().as_millis() as u64;
        self.beats[rank].store(ms, Ordering::Relaxed);
    }

    /// Millis since `rank`'s last heartbeat.
    pub fn millis_since_beat(&self, rank: usize) -> u64 {
        let now = self.start.elapsed().as_millis() as u64;
        now.saturating_sub(self.beats[rank].load(Ordering::Relaxed))
    }

    /// Record a completed step for `rank`. `work` is the step's local work
    /// time (communication excluded): it feeds the straggler EWMA
    /// (α = 1/4, integer micros — deterministic) and the progress clock
    /// that the wedged-vs-slow heuristic reads. Also counts as a beat.
    pub fn note_step(&self, rank: usize, global_step: u64, work: Duration) {
        let us = work.as_micros().min(u64::MAX as u128) as u64;
        let n = self.step_samples[rank].fetch_add(1, Ordering::Relaxed);
        let next = if n == 0 {
            us
        } else {
            let prev = self.work_ewma_us[rank].load(Ordering::Relaxed);
            (3 * prev + us) / 4
        };
        self.work_ewma_us[rank].store(next, Ordering::Relaxed);
        self.steps[rank].store(global_step + 1, Ordering::Relaxed);
        self.progress[rank]
            .store(self.start.elapsed().as_millis() as u64, Ordering::Relaxed);
        self.beat(rank);
    }

    /// Last completed global step `rank` reported on this mesh.
    pub fn last_step(&self, rank: usize) -> Option<u64> {
        match self.steps[rank].load(Ordering::Relaxed) {
            0 => None,
            s => Some(s - 1),
        }
    }

    /// EWMA of `rank`'s per-step local work, in milliseconds.
    pub fn step_ewma_ms(&self, rank: usize) -> Option<f64> {
        if self.step_samples[rank].load(Ordering::Relaxed) == 0 {
            None
        } else {
            Some(self.work_ewma_us[rank].load(Ordering::Relaxed) as f64 / 1000.0)
        }
    }

    /// How many steps have fed `rank`'s EWMA on this mesh.
    pub fn step_samples(&self, rank: usize) -> u64 {
        self.step_samples[rank].load(Ordering::Relaxed)
    }

    /// Millis since `rank` last completed a step — measured from mesh
    /// creation while no step has completed yet.
    pub fn millis_since_progress(&self, rank: usize) -> u64 {
        let now = self.start.elapsed().as_millis() as u64;
        now.saturating_sub(self.progress[rank].load(Ordering::Relaxed))
    }

    /// Mark `rank`'s worker thread as exited (cleanly or not): the monitor
    /// stops expecting heartbeats from it.
    pub fn mark_done(&self, rank: usize) {
        self.done[rank].store(true, Ordering::Release);
    }

    pub fn is_done(&self, rank: usize) -> bool {
        self.done[rank].load(Ordering::Acquire)
    }

    /// Declare `rank` dead. Raises the mesh-wide abort flag, so every
    /// in-flight `recv` on every surviving rank unwinds within one
    /// [`WAIT_SLICE`] instead of waiting on a message that will never come.
    pub fn mark_dead(&self, rank: usize) {
        self.dead[rank].store(true, Ordering::Release);
        let _ = self.first_dead.compare_exchange(
            usize::MAX,
            rank,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        self.abort.store(true, Ordering::Release);
    }

    pub fn is_dead(&self, rank: usize) -> bool {
        self.dead[rank].load(Ordering::Acquire)
    }

    pub fn aborted(&self) -> bool {
        self.abort.load(Ordering::Acquire)
    }

    /// The rank whose death triggered the abort, if any.
    pub fn first_dead(&self) -> Option<usize> {
        match self.first_dead.load(Ordering::Acquire) {
            usize::MAX => None,
            r => Some(r),
        }
    }

    /// All ranks currently marked dead.
    pub fn dead_ranks(&self) -> Vec<usize> {
        (0..self.dead.len()).filter(|&r| self.is_dead(r)).collect()
    }

    /// Fault check on the `src → this rank` edge: errors once `src` is
    /// dead or the mesh is aborting.
    fn check_edge(&self, src: usize) -> Result<(), MeshError> {
        if self.is_dead(src) {
            return Err(MeshError::PeerDead { rank: src });
        }
        if self.aborted() {
            return Err(MeshError::Aborted {
                origin: self.first_dead().unwrap_or(usize::MAX),
            });
        }
        Ok(())
    }
}

/// The wedged-vs-slow heuristic behind every death declaration that rests
/// on *silence* rather than a dropped socket. A rank is presumed wedged
/// only when BOTH its heartbeat is stale past `timeout_ms` AND it has not
/// completed a step within its progress allowance: `timeout_ms + 2 × its
/// own step-time EWMA` once steps have been reported, or `3 × timeout_ms`
/// before the first step lands (a phase's opening step gets triple the
/// timeout). A slow-but-advancing rank therefore survives timeouts shorter
/// than its step time, while a genuinely hung rank is still declared dead
/// in bounded time.
pub fn presumed_wedged(
    staleness_ms: u64,
    timeout_ms: u64,
    advance_age_ms: u64,
    step_ms_ewma: Option<f64>,
) -> bool {
    if staleness_ms <= timeout_ms {
        return false;
    }
    let allowance = match step_ms_ewma {
        Some(e) => timeout_ms as f64 + 2.0 * e,
        None => 3.0 * timeout_ms as f64,
    };
    advance_age_ms as f64 > allowance
}

/// Wire payload. FP32 is the paper's BN-stat path; FP16 the gradient path.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl Payload {
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::F32(v) => 4 * v.len() as u64,
            Payload::F16(v) => 2 * v.len() as u64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One tagged message in flight.
#[derive(Debug)]
pub(crate) struct Msg {
    pub(crate) src: usize,
    pub(crate) tag: u64,
    pub(crate) payload: Payload,
}

/// Shared per-mesh traffic counters (lock-free). Both transports count the
/// same **logical** payload bytes — frame headers and control traffic on
/// the TCP path are excluded — so a collective's byte volume is
/// transport-invariant and tests can compare the two directly.
#[derive(Debug, Default)]
pub struct Counters {
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
    pub messages: AtomicU64,
    /// Highest tag any rank has sent with — lets tests verify that a
    /// collective stays inside its declared `tag_span` window.
    pub max_tag: AtomicU64,
    /// Established connections healed by re-dial + resync instead of a
    /// death declaration (TCP mesh only; always 0 with reconnect off).
    pub reconnects: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.bytes_sent.load(Ordering::Relaxed),
            self.bytes_received.load(Ordering::Relaxed),
            self.messages.load(Ordering::Relaxed),
        )
    }

    /// Highest tag observed on any send since the last reset.
    pub fn max_tag_seen(&self) -> u64 {
        self.max_tag.load(Ordering::Relaxed)
    }

    /// Connections healed by the TCP reconnect path since the last reset.
    pub fn reconnects_seen(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.bytes_received.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
        self.max_tag.store(0, Ordering::Relaxed);
        self.reconnects.store(0, Ordering::Relaxed);
    }
}

/// Default high-water cap on one rank's inbox. Sized far above any
/// legitimate schedule's in-flight message count (the bucketed pipeline
/// keeps a handful of tag windows open; chaos dup/reorder at most doubles
/// them), so a healthy run never touches it — it exists to convert a
/// runaway flood into a typed [`MeshError::InboxOverflow`] instead of
/// unbounded memory growth.
pub const INBOX_CAP: usize = 8192;

/// One rank's inbox: a condvar-fronted queue. Producers (in-memory peer
/// sends, TCP reader threads) push and notify; the single consumer (the
/// rank's `recv` loop) parks on the condvar instead of sleep-polling, so a
/// blocked rank burns no CPU and wakes the moment a message lands. The
/// queue is bounded by a high-water `cap`: a push at the cap is refused
/// with a typed error, and the `dropped` / `high_water` tallies record
/// exactly what the bound did.
#[derive(Debug)]
pub(crate) struct Inbox {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
    cap: usize,
    /// Messages refused at the cap.
    pub(crate) dropped: AtomicU64,
    /// Deepest the queue has ever been.
    pub(crate) high_water: AtomicU64,
}

impl Default for Inbox {
    fn default() -> Self {
        Self::with_cap(INBOX_CAP)
    }
}

impl Inbox {
    pub(crate) fn with_cap(cap: usize) -> Self {
        Self {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            cap,
            dropped: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
        }
    }

    pub(crate) fn push(&self, msg: Msg) -> Result<(), MeshError> {
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.cap {
            drop(q);
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return Err(MeshError::InboxOverflow {
                len: self.cap,
                cap: self.cap,
            });
        }
        q.push_back(msg);
        let depth = q.len() as u64;
        drop(q);
        self.high_water.fetch_max(depth, Ordering::Relaxed);
        self.cv.notify_one();
        Ok(())
    }

    /// Pop the oldest message, parking for at most `slice` when empty.
    /// `None` means the slice elapsed (or a spurious wake found the queue
    /// still empty) — the caller re-checks health and parks again.
    pub(crate) fn pop_timeout(&self, slice: Duration) -> Option<Msg> {
        let mut q = self.q.lock().unwrap();
        if let Some(m) = q.pop_front() {
            return Some(m);
        }
        let (mut q, _) = self.cv.wait_timeout(q, slice).unwrap();
        q.pop_front()
    }
}

/// Upper bound on parked scratch buffers per dtype (bounds memory when a
/// caller recycles far more than it sends).
const FREELIST_CAP: usize = 32;

/// Per-endpoint scratch-buffer freelists. Receive paths recycle consumed
/// payload storage here; send paths draw from it instead of allocating per
/// hop. In a steady ring schedule each rank receives about as much as it
/// sends, so buffers circulate recv → freelist → next send and the
/// per-hop allocation rate drops to ~zero after warmup.
#[derive(Debug, Default)]
pub struct Scratch {
    free_f32: Vec<Vec<f32>>,
    free_f16: Vec<Vec<u16>>,
    hits: u64,
}

impl Scratch {
    /// Take an **empty** f32 scratch buffer with at least `capacity_hint`
    /// reserved — from the freelist when one is parked, freshly allocated
    /// otherwise.
    pub fn alloc_f32(&mut self, capacity_hint: usize) -> Vec<f32> {
        match self.free_f32.pop() {
            Some(mut v) => {
                self.hits += 1;
                v.clear();
                v.reserve(capacity_hint);
                v
            }
            None => Vec::with_capacity(capacity_hint),
        }
    }

    /// Take a zero-filled f16 scratch buffer of exactly `len` elements.
    /// Recycled buffers are cleared before resizing, so a longer previous
    /// payload can never leak a stale tail into a shorter message.
    pub fn alloc_f16(&mut self, len: usize) -> Vec<u16> {
        let mut v = match self.free_f16.pop() {
            Some(v) => {
                self.hits += 1;
                v
            }
            None => Vec::new(),
        };
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Park a consumed f32 buffer for reuse by a later send/receive.
    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.free_f32.len() < FREELIST_CAP {
            self.free_f32.push(v);
        }
    }

    /// Park a consumed f16 buffer for reuse by a later send/receive.
    pub fn recycle_f16(&mut self, v: Vec<u16>) {
        if self.free_f16.len() < FREELIST_CAP {
            self.free_f16.push(v);
        }
    }

    /// Park a consumed payload's storage whatever its dtype.
    pub fn recycle(&mut self, p: Payload) {
        match p {
            Payload::F32(v) => self.recycle_f32(v),
            Payload::F16(v) => self.recycle_f16(v),
        }
    }

    /// How many scratch buffers were served from the freelist instead of
    /// the allocator (observability for the reuse tests).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    #[cfg(test)]
    pub(crate) fn parked_f32(&self) -> usize {
        self.free_f32.len()
    }
}

/// The endpoint state both transports share: identity, the condvar inbox,
/// the MPI-style pending map, counters/health handles, the per-recv
/// deadline and the scratch freelists. Concrete endpoints embed one and
/// layer their channel (peer inboxes / TCP sockets) on top.
#[derive(Debug)]
pub(crate) struct Core {
    pub(crate) rank: usize,
    pub(crate) n: usize,
    pub(crate) inbox: Arc<Inbox>,
    /// Out-of-order arrivals parked per `(src, tag)`. `VecDeque` keeps
    /// pops O(1) under bursts, and entries are removed as soon as they
    /// drain so the map cannot grow without bound across a run.
    pub(crate) pending: HashMap<(usize, u64), VecDeque<Payload>>,
    pub(crate) counters: Arc<Counters>,
    pub(crate) health: Arc<Health>,
    /// Hard per-`recv` wait bound. `None` (the default) means wait until
    /// the health table says otherwise; the coordinator sets it to the
    /// fault config's `rank_timeout` as a belt-and-braces bound against
    /// undetected hangs.
    pub(crate) recv_deadline: Option<Duration>,
    pub(crate) scratch: Scratch,
}

impl Core {
    pub(crate) fn new(
        rank: usize,
        n: usize,
        inbox: Arc<Inbox>,
        counters: Arc<Counters>,
        health: Arc<Health>,
    ) -> Self {
        Self {
            rank,
            n,
            inbox,
            pending: HashMap::new(),
            counters,
            health,
            recv_deadline: None,
            scratch: Scratch::default(),
        }
    }

    /// Pre-send fault check + traffic accounting shared by both transports.
    pub(crate) fn check_send(&self, dst: usize) -> Result<()> {
        if dst < self.n {
            self.health
                .check_edge(dst)
                .map_err(anyhow::Error::new)
                .with_context(|| format!("rank {} send to {dst}", self.rank))?;
        }
        Ok(())
    }

    pub(crate) fn note_sent(&self, tag: u64, bytes: u64) {
        self.counters.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.max_tag.fetch_max(tag, Ordering::Relaxed);
    }

    /// Blocking receive of the message matching `(src, tag)` — but never
    /// an *unbounded* block: the condvar wait runs in [`WAIT_SLICE`]
    /// bounds, each expiry re-checking the shared health table (and
    /// ticking this rank's own heartbeat), so a dead peer or a mesh abort
    /// surfaces as a typed [`MeshError`] within one slice instead of
    /// deadlocking the collective. Arrivals cut the wait short, so the
    /// slice adds no latency to the healthy path.
    ///
    /// Messages from other (src, tag) pairs arriving first are parked and
    /// delivered to their own matching receive later (MPI-style matching).
    pub(crate) fn recv_match(&mut self, src: usize, tag: u64) -> Result<Payload> {
        self.health
            .check_edge(src)
            .map_err(anyhow::Error::new)
            .with_context(|| format!("rank {} recv from {src} tag {tag}", self.rank))?;
        if let Entry::Occupied(mut e) = self.pending.entry((src, tag)) {
            // queues are dropped when drained, so an entry is never empty
            let p = e.get_mut().pop_front().expect("empty pending queue kept");
            if e.get().is_empty() {
                e.remove();
            }
            self.counters
                .bytes_received
                .fetch_add(p.wire_bytes(), Ordering::Relaxed);
            return Ok(p);
        }
        let mut deadline = self.recv_deadline.map(|d| Instant::now() + d);
        loop {
            match self.inbox.pop_timeout(WAIT_SLICE) {
                Some(msg) => {
                    if msg.src == src && msg.tag == tag {
                        self.counters
                            .bytes_received
                            .fetch_add(msg.payload.wire_bytes(), Ordering::Relaxed);
                        return Ok(msg.payload);
                    }
                    self.pending
                        .entry((msg.src, msg.tag))
                        .or_default()
                        .push_back(msg.payload);
                }
                None => {
                    // Still waiting: we are alive (beat), but is the peer?
                    self.health.beat(self.rank);
                    self.health
                        .check_edge(src)
                        .map_err(anyhow::Error::new)
                        .with_context(|| {
                            format!("rank {} recv from {src} tag {tag}", self.rank)
                        })?;
                    if let (Some(dl), Some(d)) = (deadline, self.recv_deadline) {
                        if Instant::now() >= dl {
                            // The peer outlasted the hard bound — but a peer
                            // that is provably *advancing* (a slow step, not
                            // a hang) gets the deadline re-armed instead of
                            // a death sentence. With no telemetry for it
                            // (separate-process peers), the allowance decays
                            // to the legacy hard bound.
                            let timeout_ms = d.as_millis() as u64;
                            if !presumed_wedged(
                                self.health.millis_since_beat(src),
                                timeout_ms,
                                self.health.millis_since_progress(src),
                                self.health.step_ewma_ms(src),
                            ) {
                                deadline = Some(Instant::now() + d);
                                continue;
                            }
                            // Declare it dead so the rest of the mesh
                            // unwinds too.
                            self.health.mark_dead(src);
                            return Err(anyhow::Error::new(MeshError::PeerDead {
                                rank: src,
                            }))
                            .with_context(|| {
                                format!(
                                    "rank {} recv from {src} tag {tag}: deadline \
                                     {:?} exceeded",
                                    self.rank, self.recv_deadline
                                )
                            });
                        }
                    }
                }
            }
        }
    }

    pub(crate) fn pending_messages(&self) -> usize {
        self.pending.values().map(|q| q.len()).sum()
    }
}

/// What a collective schedule needs from a channel: tagged point-to-point
/// `send`/`recv` of [`Payload`]s inside a fixed-size rank mesh, the shared
/// [`Counters`] / [`Health`] tables, and the scratch freelists that keep
/// the bucketed pipeline's message rate from turning into allocation
/// churn. Every schedule takes `&mut dyn Transport`, so the in-memory
/// [`Endpoint`] and the socket-backed [`TcpEndpoint`] are interchangeable
/// under all of them.
pub trait Transport: Send {
    fn rank(&self) -> usize;

    fn world_size(&self) -> usize;

    fn counters(&self) -> &Counters;

    /// Shared counter block (snapshot it *after* joining all rank threads —
    /// per-thread snapshots race with peers still in flight).
    fn counters_arc(&self) -> Arc<Counters>;

    /// Shared health table of this endpoint's mesh (the coordinator's
    /// heartbeat monitor scans it; tests use it to kill ranks).
    fn health(&self) -> &Health;

    fn health_arc(&self) -> Arc<Health>;

    /// Bound every subsequent blocking `recv` to `d` of wall-clock wait;
    /// on expiry the awaited peer is marked dead and the receive fails
    /// with [`MeshError::PeerDead`]. `None` removes the bound.
    fn set_recv_deadline(&mut self, d: Option<Duration>);

    /// Send `payload` to `dst` under `tag`. Never blocks; fails fast when
    /// `dst` is already marked dead or the mesh is aborting.
    fn send(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()>;

    /// Blocking receive of the message matching `(src, tag)`; unwinds with
    /// a typed [`MeshError`] on peer death / mesh abort instead of
    /// hanging. See [`Core::recv_match`] for the matching semantics.
    fn recv(&mut self, src: usize, tag: u64) -> Result<Payload>;

    /// Number of parked out-of-order messages (tests assert this drains to
    /// zero so the pending map cannot leak across a long run).
    fn pending_messages(&self) -> usize;

    fn scratch(&self) -> &Scratch;

    fn scratch_mut(&mut self) -> &mut Scratch;

    /// Tick this rank's heartbeat (also ticked automatically while blocked
    /// in `recv` — call it once per step so compute-heavy gaps still beat).
    fn heartbeat(&self) {
        self.health().beat(self.rank());
    }

    /// Record a completed training step: `global_step` finished and took
    /// `work` of local work time (communication excluded). Feeds the
    /// shared [`Health`] straggler telemetry — call it once per step,
    /// after the optimizer apply.
    fn note_step(&self, global_step: u64, work: Duration) {
        self.health().note_step(self.rank(), global_step, work);
    }

    /// Declare a peer (or this rank itself) dead; aborts the whole mesh.
    fn mark_dead(&self, rank: usize) {
        self.health().mark_dead(rank);
    }

    /// Copy `data` into a freelist-backed buffer and send it (no per-hop
    /// allocation once the freelist has warmed up).
    fn send_f32(&mut self, dst: usize, tag: u64, data: &[f32]) -> Result<()> {
        let mut buf = self.scratch_mut().alloc_f32(data.len());
        buf.extend_from_slice(data);
        self.send(dst, tag, Payload::F32(buf))
    }

    fn send_f16(&mut self, dst: usize, tag: u64, data: Vec<u16>) -> Result<()> {
        self.send(dst, tag, Payload::F16(data))
    }

    fn alloc_f32(&mut self, capacity_hint: usize) -> Vec<f32> {
        self.scratch_mut().alloc_f32(capacity_hint)
    }

    fn alloc_f16(&mut self, len: usize) -> Vec<u16> {
        self.scratch_mut().alloc_f16(len)
    }

    fn recycle_f32(&mut self, v: Vec<f32>) {
        self.scratch_mut().recycle_f32(v)
    }

    fn recycle_f16(&mut self, v: Vec<u16>) {
        self.scratch_mut().recycle_f16(v)
    }

    fn recycle(&mut self, p: Payload) {
        self.scratch_mut().recycle(p)
    }

    fn freelist_hits(&self) -> u64 {
        self.scratch().hits()
    }

    /// Receive and require an f32 payload (wire-format mismatch is a bug).
    fn recv_f32(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        match self.recv(src, tag)? {
            Payload::F32(v) => Ok(v),
            Payload::F16(_) => Err(anyhow!(
                "rank {}: expected f32 wire payload from {src} tag {tag}, got f16",
                self.rank()
            )),
        }
    }

    /// Receive and require an f16 payload.
    fn recv_f16(&mut self, src: usize, tag: u64) -> Result<Vec<u16>> {
        match self.recv(src, tag)? {
            Payload::F16(v) => Ok(v),
            Payload::F32(_) => Err(anyhow!(
                "rank {}: expected f16 wire payload from {src} tag {tag}, got f32",
                self.rank()
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_delays_are_deterministic_bounded_and_grow() {
        let b = BackoffConfig::default();
        for attempt in 0..b.attempts {
            let d = b.delay(attempt, 7);
            assert_eq!(d, b.delay(attempt, 7), "attempt {attempt} not deterministic");
            let lo = b.base.as_secs_f64() * (1.0 - b.jitter);
            let hi = b.max.as_secs_f64() * (1.0 + b.jitter);
            let s = d.as_secs_f64();
            assert!(s >= lo - 1e-12 && s <= hi + 1e-12, "attempt {attempt}: {s}");
        }
        // different salts de-synchronize the schedule
        assert_ne!(b.delay(0, 1), b.delay(0, 2));
        // nominal growth: late attempts sit at the cap, above early ones
        let early = b.delay(0, 7).as_secs_f64();
        let late = b.delay(b.attempts - 1, 7).as_secs_f64();
        assert!(late > early, "late {late} !> early {early}");
        // the budget covers every possible delay sum
        let worst: f64 = (0..b.attempts).map(|a| b.delay(a, 7).as_secs_f64()).sum();
        assert!(b.total_budget().as_secs_f64() >= worst - 1e-9);
    }

    #[test]
    fn zero_jitter_is_exactly_exponential() {
        let b = BackoffConfig {
            base: Duration::from_millis(100),
            max: Duration::from_millis(400),
            attempts: 4,
            jitter: 0.0,
        };
        let ds: Vec<u128> = (0..4).map(|a| b.delay(a, 99).as_millis()).collect();
        assert_eq!(ds, vec![100, 150, 225, 337]);
    }

    #[test]
    fn mesh_error_display_names_the_limit() {
        let e = MeshError::FrameTooLarge { len: 100, max: 64 };
        assert!(e.to_string().contains("max_frame_bytes"));
        let e = MeshError::Truncated { got: 3, want: 17 };
        assert!(e.to_string().contains("3 of 17"));
        let e = MeshError::InboxOverflow { len: 8, cap: 8 };
        assert!(e.to_string().contains("cap 8"));
    }

    /// Regression (bounded inboxes): pushes at the high-water cap are
    /// refused with the typed overflow error, the dropped / high-water
    /// tallies record exactly what happened, and the queue stays at the
    /// cap instead of growing without bound.
    #[test]
    fn inbox_refuses_pushes_past_its_cap_and_counts_them() {
        let inbox = Inbox::with_cap(4);
        let msg = |i: u64| Msg {
            src: 0,
            tag: i,
            payload: Payload::F32(vec![i as f32]),
        };
        for i in 0..4 {
            inbox.push(msg(i)).unwrap();
        }
        for i in 4..7 {
            let err = inbox.push(msg(i)).unwrap_err();
            assert_eq!(err, MeshError::InboxOverflow { len: 4, cap: 4 });
        }
        assert_eq!(inbox.dropped.load(Ordering::Relaxed), 3);
        assert_eq!(inbox.high_water.load(Ordering::Relaxed), 4);
        // Draining one slot re-admits exactly one message.
        assert!(inbox.pop_timeout(Duration::from_millis(1)).is_some());
        inbox.push(msg(7)).unwrap();
        assert!(inbox.push(msg(8)).is_err());
        assert_eq!(inbox.dropped.load(Ordering::Relaxed), 4);
    }

    /// Step telemetry: the EWMA warms up from the first sample, tracks
    /// later ones at α = 1/4, and the step / progress clocks advance.
    #[test]
    fn health_step_telemetry_tracks_ewma_and_progress() {
        let h = Health::new(2);
        assert_eq!(h.last_step(1), None);
        assert_eq!(h.step_ewma_ms(1), None);
        assert_eq!(h.step_samples(1), 0);
        h.note_step(1, 10, Duration::from_millis(100));
        assert_eq!(h.last_step(1), Some(10));
        assert_eq!(h.step_ewma_ms(1), Some(100.0));
        // α = 1/4: 0.75 × 100ms + 0.25 × 20ms = 80ms
        h.note_step(1, 11, Duration::from_millis(20));
        assert_eq!(h.step_ewma_ms(1), Some(80.0));
        assert_eq!(h.step_samples(1), 2);
        assert!(h.millis_since_progress(1) < 1000);
        // rank 0 never stepped: its progress age is the mesh age
        assert_eq!(h.last_step(0), None);
    }

    /// The wedged-vs-slow heuristic: a stale-but-advancing rank is spared,
    /// a stale rank past its progress allowance is not, and the no-sample
    /// fallback grants triple the timeout.
    #[test]
    fn presumed_wedged_spares_advancing_ranks() {
        // Heartbeat fresh: never wedged, however old the progress.
        assert!(!presumed_wedged(100, 100, 10_000, None));
        // Stale but advanced recently relative to its own pace.
        assert!(!presumed_wedged(500, 300, 450, Some(400.0)));
        // Stale and silent past timeout + 2 × EWMA: wedged.
        assert!(presumed_wedged(2000, 300, 1200, Some(400.0)));
        // No samples yet: allowance is 3 × timeout.
        assert!(!presumed_wedged(500, 300, 850, None));
        assert!(presumed_wedged(500, 300, 950, None));
    }
}
