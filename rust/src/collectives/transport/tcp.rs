//! The socket transport: the same fully-connected rank mesh over
//! `std::net` TCP, speaking the length-prefixed [`frame`] codec.
//!
//! Topology is a pairwise full mesh: for every pair `(i, j)` with
//! `i < j`, rank `i` dials rank `j`'s listener and introduces itself with
//! a `hello` control frame. Each connection is used full-duplex: the
//! owning endpoint writes its outbound frames, and a dedicated **reader
//! thread** decodes inbound frames into the endpoint's condvar inbox — so
//! the `recv` path (matching, health checks, deadlines, counters) is the
//! exact same [`Core`] code the in-memory mesh runs, and "socket
//! readable" needs no polling anywhere.
//!
//! **Death = a dropped socket.** A reader that hits EOF or a stream error
//! marks its peer dead in the shared [`Health`] table — unless the close
//! was *clean*: an endpoint being dropped normally (end of phase, or a
//! victim unwinding from someone else's failure) first sends a `bye`
//! control frame to every peer. A rank that knows itself dead
//! (`health.is_dead(own_rank)`) deliberately skips the `bye`, so its
//! sockets drop cold and every peer's reader converts that into
//! `mark_dead` — which is exactly how a killed worker **process** is
//! detected: the kernel closes its sockets, and the survivors unwind into
//! the elastic recovery path with no coordinator round-trip needed.
//!
//! [`TcpMesh::loopback`] builds all `n` endpoints in-process over
//! 127.0.0.1 (sharing one [`Counters`]/[`Health`] like the in-memory
//! mesh — this is what `[transport] mode = "tcp"` runs under `train`, and
//! what the conformance suite compares against the in-memory control);
//! [`connect_mesh`] builds one endpoint per OS process for the real
//! coordinator/worker mode.

use std::io::{ErrorKind, Write as _};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use super::frame::{self, DEFAULT_MAX_FRAME_BYTES};
use super::{Core, Counters, Health, Inbox, MeshError, Msg, Payload, Scratch, Transport};

/// How long [`connect_mesh`] keeps re-dialing a peer whose listener is
/// not up yet (fresh worker processes race each other to bind).
const DIAL_RETRY: Duration = Duration::from_millis(100);
const DIAL_ATTEMPTS: usize = 100;

/// Factory for socket-backed meshes.
pub struct TcpMesh;

impl TcpMesh {
    /// Build `n` endpoints connected over loopback TCP inside this
    /// process, sharing one counter block and one health table — the
    /// drop-in socket twin of [`Mesh::new`](super::Mesh::new).
    pub fn loopback(n: usize) -> Result<Vec<TcpEndpoint>> {
        Self::loopback_with(n, DEFAULT_MAX_FRAME_BYTES)
    }

    /// [`Self::loopback`] with an explicit frame-size cap.
    pub fn loopback_with(n: usize, max_frame_bytes: usize) -> Result<Vec<TcpEndpoint>> {
        assert!(n > 0, "mesh needs at least one rank");
        let counters = Arc::new(Counters::default());
        let health = Arc::new(Health::new(n));
        let listener = TcpListener::bind("127.0.0.1:0").context("binding loopback mesh")?;
        let addr = listener.local_addr()?;
        // Pair (i, j): i dials, j accepts. Dials complete through the
        // listen backlog, so a single thread can connect-then-accept.
        let mut streams: Vec<Vec<Option<TcpStream>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                let dialer = TcpStream::connect(addr)
                    .with_context(|| format!("loopback dial for pair ({i},{j})"))?;
                let (acceptor, _) = listener.accept()?;
                streams[i][j] = Some(dialer);
                streams[j][i] = Some(acceptor);
            }
        }
        streams
            .into_iter()
            .enumerate()
            .map(|(rank, links)| {
                assemble(rank, n, links, counters.clone(), health.clone(), max_frame_bytes)
            })
            .collect()
    }
}

/// Build one rank's endpoint of a **multi-process** mesh. `peers[r]` is
/// rank `r`'s data-listener address (`peers[rank]` itself is unused);
/// `listener` is this rank's own, already bound. Dials every higher rank
/// (introducing itself with a `hello` control frame, retrying while the
/// peer's listener comes up) and accepts one connection from every lower
/// rank. `counters`/`health` are this process's local tables — in
/// process mode each worker owns its own copy of both.
///
/// Both the dial and accept loops watch `health`'s abort flag: if the
/// coordinator cancels the attempt (another rank died before the mesh
/// finished forming), the call unwinds with a [`MeshError`] instead of
/// blocking on a peer that will never connect.
pub fn connect_mesh(
    rank: usize,
    peers: &[String],
    listener: &TcpListener,
    counters: Arc<Counters>,
    health: Arc<Health>,
    max_frame_bytes: usize,
) -> Result<TcpEndpoint> {
    let n = peers.len();
    assert!(rank < n, "rank {rank} outside mesh of {n}");
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut wbuf = Vec::new();
    // Dial up first: connects land in the peers' listen backlogs, so the
    // dial/accept order across ranks cannot deadlock.
    for (j, addr) in peers.iter().enumerate().skip(rank + 1) {
        let mut s = dial_retry(addr, &health)
            .with_context(|| format!("rank {rank} dialing rank {j} at {addr}"))?;
        frame::write_control(
            &mut s,
            &mut wbuf,
            &format!(r#"{{"type":"hello","rank":{rank}}}"#),
        )
        .with_context(|| format!("rank {rank} hello to rank {j}"))?;
        links[j] = Some(s);
    }
    // Accept one connection from every lower rank; the hello frame says
    // which one (accept order is whatever the network delivers). The
    // listener runs non-blocking so the abort flag is honoured while
    // waiting.
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + DIAL_RETRY * DIAL_ATTEMPTS as u32;
    let mut body = Vec::new();
    for _ in 0..rank {
        let (mut s, from) = loop {
            check_abort(&health)?;
            match listener.accept() {
                Ok(pair) => break pair,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        bail!("rank {rank} timed out waiting for lower-rank mesh peers");
                    }
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(anyhow!(e).context("accepting mesh peer")),
            }
        };
        s.set_nonblocking(false)?;
        let h = frame::read_frame(&mut s, max_frame_bytes, &mut body)?
            .ok_or_else(|| anyhow!("mesh peer at {from} closed before hello"))?;
        if h.kind != frame::KIND_CONTROL {
            bail!("mesh peer at {from} sent frame kind {} before hello", h.kind);
        }
        let j = crate::util::json::Json::parse(std::str::from_utf8(&body)?)?
            .get("rank")?
            .as_usize()?;
        if j >= rank || links[j].is_some() {
            bail!("mesh hello from unexpected rank {j} (this rank: {rank})");
        }
        links[j] = Some(s);
    }
    listener.set_nonblocking(false)?;
    assemble(rank, n, links, counters, health, max_frame_bytes)
}

fn check_abort(health: &Health) -> Result<()> {
    if health.aborted() {
        bail!(MeshError::Aborted {
            origin: health.first_dead().unwrap_or(0),
        });
    }
    Ok(())
}

fn dial_retry(addr: &str, health: &Health) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..DIAL_ATTEMPTS {
        check_abort(health)?;
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                thread::sleep(DIAL_RETRY);
            }
        }
    }
    Err(last.expect("at least one dial attempt").into())
}

/// Wrap pairwise streams into an endpoint: set NODELAY (collective hops
/// are latency-bound small-to-medium writes), clone each stream for its
/// reader thread, and start the readers.
fn assemble(
    rank: usize,
    n: usize,
    links: Vec<Option<TcpStream>>,
    counters: Arc<Counters>,
    health: Arc<Health>,
    max_frame_bytes: usize,
) -> Result<TcpEndpoint> {
    let inbox = Arc::new(Inbox::default());
    let closing = Arc::new(AtomicBool::new(false));
    let mut writers = Vec::with_capacity(n);
    let mut readers = Vec::new();
    for (peer, link) in links.into_iter().enumerate() {
        match link {
            Some(s) => {
                s.set_nodelay(true)?;
                let reader_stream = s.try_clone()?;
                readers.push(spawn_reader(
                    rank,
                    peer,
                    reader_stream,
                    inbox.clone(),
                    health.clone(),
                    closing.clone(),
                    max_frame_bytes,
                ));
                writers.push(Some(s));
            }
            None => writers.push(None),
        }
    }
    Ok(TcpEndpoint {
        core: Core::new(rank, n, inbox, counters, health),
        writers,
        wbuf: Vec::new(),
        readers,
        closing,
        max_frame_bytes,
    })
}

/// One reader thread per peer stream: decode frames into the shared
/// inbox; translate an unclean close into `mark_dead(peer)`.
fn spawn_reader(
    rank: usize,
    peer: usize,
    mut stream: TcpStream,
    inbox: Arc<Inbox>,
    health: Arc<Health>,
    closing: Arc<AtomicBool>,
    max_frame_bytes: usize,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("tcp-mesh-r{rank}p{peer}"))
        .spawn(move || {
            let mut body = Vec::new();
            // `bye` received: the peer is closing on purpose; the EOF that
            // follows is not a death.
            let mut clean = false;
            loop {
                match frame::read_frame(&mut stream, max_frame_bytes, &mut body) {
                    Ok(Some(h)) => match h.kind {
                        // The only control traffic on an established mesh
                        // link is the close handshake.
                        frame::KIND_CONTROL => clean = true,
                        _ => match frame::decode_payload(h.kind, &body, Vec::new(), Vec::new()) {
                            Ok(payload) => inbox.push(Msg {
                                src: h.src as usize,
                                tag: h.tag,
                                payload,
                            }),
                            // A malformed frame means the stream is out of
                            // sync — unrecoverable for this link.
                            Err(_) => break,
                        },
                    },
                    Ok(None) => break, // EOF
                    Err(_) => break,   // truncated / oversized / io error
                }
            }
            if !clean && !closing.load(Ordering::Acquire) && !health.is_dead(peer) {
                health.mark_dead(peer);
            }
        })
        .expect("spawning tcp mesh reader")
}

/// One rank's socket-backed view of the mesh. Same [`Transport`] surface
/// as the in-memory [`Endpoint`](super::Endpoint): `recv` runs the shared
/// matching/health/deadline loop over the inbox the reader threads feed,
/// and `send` frames the payload into the peer's stream (recycling the
/// payload storage into this endpoint's freelist, so the high-rate
/// bucketed pipeline reuses buffers on the socket path too).
pub struct TcpEndpoint {
    core: Core,
    /// writers[r] = the stream to rank `r` (`None` for this rank itself).
    writers: Vec<Option<TcpStream>>,
    /// Reusable frame-serialization buffer.
    wbuf: Vec<u8>,
    readers: Vec<thread::JoinHandle<()>>,
    /// Tells this endpoint's readers that the sockets are being shut down
    /// on purpose, so the EOF they see is not a peer death.
    closing: Arc<AtomicBool>,
    max_frame_bytes: usize,
}

impl TcpEndpoint {
    pub fn rank(&self) -> usize {
        self.core.rank
    }

    pub fn world_size(&self) -> usize {
        self.core.n
    }

    pub fn counters(&self) -> &Counters {
        &self.core.counters
    }

    pub fn counters_arc(&self) -> Arc<Counters> {
        self.core.counters.clone()
    }

    pub fn health(&self) -> &Health {
        &self.core.health
    }

    pub fn health_arc(&self) -> Arc<Health> {
        self.core.health.clone()
    }

    pub fn heartbeat(&self) {
        self.core.health.beat(self.core.rank);
    }

    pub fn mark_dead(&self, rank: usize) {
        self.core.health.mark_dead(rank);
    }

    pub fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.core.recv_deadline = d;
    }

    fn send_impl(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        self.core.check_send(dst)?;
        if dst >= self.core.n {
            bail!("send to out-of-range rank {dst} (n={})", self.core.n);
        }
        let bytes = payload.wire_bytes();
        if dst == self.core.rank {
            // Self-edge: loop back through the inbox like the in-memory
            // mesh (no socket exists to ourselves).
            self.core.inbox.push(Msg { src: dst, tag, payload });
            self.core.note_sent(tag, bytes);
            return Ok(());
        }
        frame::encode_payload_frame(
            &mut self.wbuf,
            self.core.rank as u32,
            dst as u32,
            tag,
            &payload,
        );
        if self.wbuf.len() > self.max_frame_bytes + 4 {
            bail!(
                "payload of {} wire bytes exceeds max_frame_bytes {} (raise \
                 [transport] max_frame_bytes or shrink bucket_bytes)",
                bytes,
                self.max_frame_bytes
            );
        }
        let stream = self.writers[dst]
            .as_mut()
            .expect("pairwise mesh link missing");
        stream
            .write_all(&self.wbuf)
            .with_context(|| format!("rank {} tcp send to {dst} tag {tag}", self.core.rank))?;
        self.core.note_sent(tag, bytes);
        // The frame now carries the bytes; the payload storage is free.
        self.core.scratch.recycle(payload);
        Ok(())
    }
}

impl Transport for TcpEndpoint {
    fn rank(&self) -> usize {
        self.core.rank
    }

    fn world_size(&self) -> usize {
        self.core.n
    }

    fn counters(&self) -> &Counters {
        &self.core.counters
    }

    fn counters_arc(&self) -> Arc<Counters> {
        self.core.counters.clone()
    }

    fn health(&self) -> &Health {
        &self.core.health
    }

    fn health_arc(&self) -> Arc<Health> {
        self.core.health.clone()
    }

    fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.core.recv_deadline = d;
    }

    fn send(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        self.send_impl(dst, tag, payload)
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        self.core.recv_match(src, tag)
    }

    fn pending_messages(&self) -> usize {
        self.core.pending_messages()
    }

    fn scratch(&self) -> &Scratch {
        &self.core.scratch
    }

    fn scratch_mut(&mut self) -> &mut Scratch {
        &mut self.core.scratch
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        self.closing.store(true, Ordering::Release);
        // A rank that knows itself dead must drop its sockets *cold*: the
        // missing `bye` is what tells every peer's reader this was a
        // death, not a clean close.
        let dying = self.core.health.is_dead(self.core.rank);
        for (peer, link) in self.writers.iter_mut().enumerate() {
            if let Some(s) = link {
                if !dying {
                    frame::encode_frame(
                        &mut self.wbuf,
                        frame::KIND_CONTROL,
                        self.core.rank as u32,
                        peer as u32,
                        0,
                        br#"{"type":"bye"}"#,
                    );
                    let _ = s.write_all(&self.wbuf);
                }
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for TcpEndpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TcpEndpoint")
            .field("rank", &self.core.rank)
            .field("n", &self.core.n)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::MeshError;
    use super::*;
    use std::time::Instant;

    fn t<T: Transport>(ep: &mut T) -> &mut dyn Transport {
        ep
    }

    #[test]
    fn loopback_point_to_point_and_tag_matching() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        t(&mut a).send_f32(1, 1, &[1.0]).unwrap();
        t(&mut a).send_f32(1, 2, &[2.0]).unwrap();
        t(&mut a).send_f16(1, 1, vec![0x3C00]).unwrap();
        // out-of-order receive parks the earlier tag-1 messages
        assert_eq!(t(&mut b).recv_f32(0, 2).unwrap(), vec![2.0]);
        assert_eq!(t(&mut b).recv_f32(0, 1).unwrap(), vec![1.0]);
        assert_eq!(t(&mut b).recv_f16(0, 1).unwrap(), vec![0x3C00]);
        assert_eq!(b.pending_messages(), 0);
        // logical payload bytes only: 4 + 4 + 2 on each side of the wire
        let (sent, recvd, msgs) = a.counters().snapshot();
        assert_eq!((sent, recvd, msgs), (10, 10, 3));
    }

    #[test]
    fn loopback_self_send_round_trips() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let mut a = eps.remove(0);
        t(&mut a).send_f32(0, 5, &[4.0, 5.0]).unwrap();
        assert_eq!(t(&mut a).recv_f32(0, 5).unwrap(), vec![4.0, 5.0]);
    }

    /// Two "processes": separate Health/Counters per endpoint, linked by
    /// `connect_mesh`. A clean drop says `bye`, so no one is marked dead.
    #[test]
    fn clean_drop_is_not_a_death() {
        let (e0, e1) = process_pair();
        let h1 = e1.health_arc();
        drop(e0);
        // e1's reader sees bye + EOF and exits without marking rank 0 dead
        let t0 = Instant::now();
        while h1.first_dead().is_none() && t0.elapsed() < Duration::from_millis(300) {
            thread::sleep(Duration::from_millis(10));
        }
        assert!(!h1.is_dead(0), "clean close must not look like a death");
        drop(e1);
    }

    /// A socket dropped *without* `bye` — what the kernel does when a
    /// worker process dies — marks the peer dead and unwinds blocked
    /// receivers in bounded time.
    #[test]
    fn socket_drop_without_bye_marks_peer_dead() {
        let (e0, mut e1) = process_pair();
        // Rank 0 "dies": knowing itself dead suppresses the bye.
        e0.mark_dead(0);
        let t0 = Instant::now();
        drop(e0);
        let err = t(&mut e1).recv_f32(0, 0).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "recv did not unwind fast");
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 0 })
        );
        assert!(e1.health().is_dead(0));
    }

    #[test]
    fn oversized_send_is_a_clean_error() {
        let mut eps = TcpMesh::loopback_with(2, 64).unwrap();
        let mut a = eps.remove(0);
        let err = t(&mut a).send_f32(1, 0, &[0.0; 100]).unwrap_err();
        assert!(format!("{err:#}").contains("max_frame_bytes"), "{err:#}");
    }

    /// Build a 2-rank mesh the way two worker processes would: one
    /// listener and one Health/Counters pair per endpoint.
    fn process_pair() -> (TcpEndpoint, TcpEndpoint) {
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs = vec![
            l0.local_addr().unwrap().to_string(),
            l1.local_addr().unwrap().to_string(),
        ];
        let a0 = addrs.clone();
        let h = thread::spawn(move || {
            connect_mesh(
                0,
                &a0,
                &l0,
                Arc::new(Counters::default()),
                Arc::new(Health::new(2)),
                DEFAULT_MAX_FRAME_BYTES,
            )
            .unwrap()
        });
        let e1 = connect_mesh(
            1,
            &addrs,
            &l1,
            Arc::new(Counters::default()),
            Arc::new(Health::new(2)),
            DEFAULT_MAX_FRAME_BYTES,
        )
        .unwrap();
        (h.join().unwrap(), e1)
    }
}
