//! The socket transport: the same fully-connected rank mesh over
//! `std::net` TCP, speaking the length-prefixed [`frame`] codec.
//!
//! Topology is a pairwise full mesh: for every pair `(i, j)` with
//! `i < j`, rank `i` dials rank `j`'s listener and introduces itself with
//! a `hello` control frame. Each connection is used full-duplex: the
//! owning endpoint writes its outbound frames, and a dedicated **reader
//! thread** decodes inbound frames into the endpoint's condvar inbox — so
//! the `recv` path (matching, health checks, deadlines, counters) is the
//! exact same [`Core`] code the in-memory mesh runs, and "socket
//! readable" needs no polling anywhere.
//!
//! **Death = a dropped socket.** A reader that hits EOF or a stream error
//! marks its peer dead in the shared [`Health`] table — unless the close
//! was *clean*: an endpoint being dropped normally (end of phase, or a
//! victim unwinding from someone else's failure) first sends a `bye`
//! control frame to every peer. A rank that knows itself dead
//! (`health.is_dead(own_rank)`) deliberately skips the `bye`, so its
//! sockets drop cold and every peer's reader converts that into
//! `mark_dead` — which is exactly how a killed worker **process** is
//! detected: the kernel closes its sockets, and the survivors unwind into
//! the elastic recovery path with no coordinator round-trip needed.
//!
//! **Reconnect (opt-in).** With `[transport] reconnect_attempts > 0` a
//! broken established connection is no longer an instant death: the
//! dialer side of the pair re-dials with the configured backoff and runs
//! a **seq-fenced resync** handshake before the rank is declared dead.
//! Every payload frame a sender writes is first pushed into a bounded
//! replay ring and numbered; every payload frame a receiver delivers to
//! its inbox bumps a received counter. Because TCP delivers a prefix, the
//! peer's counter names exactly the undelivered suffix: on reconnect each
//! side reports its counter and the other replays its ring from there —
//! no frame is lost, none is duplicated, and a partially-written trailing
//! frame (never counted by the receiver) is simply resent whole. The
//! accept side of a re-dial is served by a small **router** thread on the
//! mesh listener. With `reconnect_attempts = 0` (the default) none of
//! this machinery is built and the transport path is byte-for-byte the
//! legacy behaviour.
//!
//! Both sides replay their suffixes synchronously while holding their own
//! link lock; the suffix is bounded by `resync_window` frames, which is
//! assumed to fit the kernel socket buffers (the window exists precisely
//! to keep replay small).
//!
//! [`TcpMesh::loopback`] builds all `n` endpoints in-process over
//! 127.0.0.1 (sharing one [`Counters`]/[`Health`] like the in-memory
//! mesh — this is what `[transport] mode = "tcp"` runs under `train`, and
//! what the conformance suite compares against the in-memory control);
//! [`connect_mesh`] builds one endpoint per OS process for the real
//! coordinator/worker mode.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Write as _};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use super::frame::{self, DEFAULT_MAX_FRAME_BYTES};
use super::{
    BackoffConfig, Core, Counters, Health, Inbox, MeshError, Msg, Payload, Scratch, Transport,
};

/// Everything a socket mesh can be configured with. `Default` is the
/// legacy behaviour: default backoff for the initial dials, no reconnect
/// (a broken established stream is a death), no fault injection.
#[derive(Debug, Clone)]
pub struct TcpOptions {
    /// Reject frames larger than this before allocating for them.
    pub max_frame_bytes: usize,
    /// Jittered exponential backoff for initial dials and re-dials.
    pub backoff: BackoffConfig,
    /// How many times a broken established connection may heal before the
    /// peer is declared dead. `0` disables reconnect entirely.
    pub reconnect_attempts: u32,
    /// How many outbound frames each link keeps replayable for resync.
    /// Replay memory is bounded by `resync_window` encoded frames.
    pub resync_window: usize,
    /// Deterministic link-fault injection (tests only).
    pub link_policy: Option<Arc<LinkPolicy>>,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            backoff: BackoffConfig::default(),
            reconnect_attempts: 0,
            resync_window: 64,
            link_policy: None,
        }
    }
}

/// Deterministic TCP-level fault injection, the socket-layer sibling of
/// [`ChaosTransport`](super::ChaosTransport): connection resets pinned to
/// an exact (src, dst, frame-sequence) triple, and one-shot partitions
/// that block the first `n` re-dial attempts of a healing link. Counters
/// record exactly what fired so tests can assert the injection happened.
#[derive(Debug, Default)]
pub struct LinkPolicy {
    /// `(src, dst, seq)`: shut the src→dst connection down immediately
    /// before src writes its `seq`-th payload frame. Sequence numbers
    /// strictly increase, so each entry fires at most once.
    resets: Vec<(usize, usize, u64)>,
    /// `(src, dst, n)`: fail src's first `n` re-dial attempts to dst.
    partitions: Vec<(usize, usize, u32)>,
    resets_injected: AtomicU64,
    dials_blocked: AtomicU64,
}

impl LinkPolicy {
    pub fn with_reset(mut self, src: usize, dst: usize, seq: u64) -> Self {
        self.resets.push((src, dst, seq));
        self
    }

    pub fn with_partition(mut self, src: usize, dst: usize, dials: u32) -> Self {
        self.partitions.push((src, dst, dials));
        self
    }

    fn reset_now(&self, src: usize, dst: usize, seq: u64) -> bool {
        if self
            .resets
            .iter()
            .any(|&(s, d, q)| s == src && d == dst && q == seq)
        {
            self.resets_injected.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    fn dial_blocked(&self, src: usize, dst: usize, attempt: u32) -> bool {
        match self
            .partitions
            .iter()
            .find(|&&(s, d, _)| s == src && d == dst)
        {
            Some(&(_, _, n)) if attempt < n => {
                self.dials_blocked.fetch_add(1, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// `(resets_injected, dials_blocked)` so far.
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.resets_injected.load(Ordering::Relaxed),
            self.dials_blocked.load(Ordering::Relaxed),
        )
    }
}

/// Factory for socket-backed meshes.
pub struct TcpMesh;

impl TcpMesh {
    /// Build `n` endpoints connected over loopback TCP inside this
    /// process, sharing one counter block and one health table — the
    /// drop-in socket twin of [`Mesh::new`](super::Mesh::new).
    pub fn loopback(n: usize) -> Result<Vec<TcpEndpoint>> {
        Self::loopback_opts(n, TcpOptions::default())
    }

    /// [`Self::loopback`] with an explicit frame-size cap.
    pub fn loopback_with(n: usize, max_frame_bytes: usize) -> Result<Vec<TcpEndpoint>> {
        Self::loopback_opts(
            n,
            TcpOptions {
                max_frame_bytes,
                ..TcpOptions::default()
            },
        )
    }

    /// [`Self::loopback`] with full [`TcpOptions`] control (reconnect,
    /// backoff, fault injection).
    pub fn loopback_opts(n: usize, opts: TcpOptions) -> Result<Vec<TcpEndpoint>> {
        assert!(n > 0, "mesh needs at least one rank");
        let counters = Arc::new(Counters::default());
        let health = Arc::new(Health::new(n));
        let listener = TcpListener::bind("127.0.0.1:0").context("binding loopback mesh")?;
        let addr = listener.local_addr()?;
        // Pair (i, j): i dials, j accepts. Dials complete through the
        // listen backlog, so a single thread can connect-then-accept.
        let mut streams: Vec<Vec<Option<TcpStream>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                let dialer = TcpStream::connect(addr)
                    .with_context(|| format!("loopback dial for pair ({i},{j})"))?;
                let (acceptor, _) = listener.accept()?;
                streams[i][j] = Some(dialer);
                streams[j][i] = Some(acceptor);
            }
        }
        // With reconnect on, the shared listener stays alive inside the
        // router thread: every in-process rank re-dials the same address.
        let redial = if opts.reconnect_attempts > 0 {
            let registry: Registry = Arc::new(Mutex::new(HashMap::new()));
            let guard = start_router(listener, registry.clone())?;
            Some(RedialCtx {
                dial_addrs: vec![addr.to_string(); n],
                registry,
                guard,
            })
        } else {
            None
        };
        streams
            .into_iter()
            .enumerate()
            .map(|(rank, links)| {
                assemble(
                    rank,
                    n,
                    links,
                    counters.clone(),
                    health.clone(),
                    &opts,
                    redial.clone(),
                )
            })
            .collect()
    }
}

/// Build one rank's endpoint of a **multi-process** mesh with legacy
/// defaults (no reconnect). See [`connect_mesh_opts`].
pub fn connect_mesh(
    rank: usize,
    peers: &[String],
    listener: &TcpListener,
    counters: Arc<Counters>,
    health: Arc<Health>,
    max_frame_bytes: usize,
) -> Result<TcpEndpoint> {
    let opts = TcpOptions {
        max_frame_bytes,
        ..TcpOptions::default()
    };
    connect_mesh_opts(rank, peers, listener, counters, health, &opts)
}

/// Build one rank's endpoint of a **multi-process** mesh. `peers[r]` is
/// rank `r`'s data-listener address (`peers[rank]` itself is unused);
/// `listener` is this rank's own, already bound. Dials every higher rank
/// (introducing itself with a `hello` control frame, retrying with the
/// configured backoff while the peer's listener comes up) and accepts one
/// connection from every lower rank. `counters`/`health` are this
/// process's local tables — in process mode each worker owns its own copy
/// of both.
///
/// Both the dial and accept loops watch `health`'s abort flag: if the
/// coordinator cancels the attempt (another rank died before the mesh
/// finished forming), the call unwinds with a [`MeshError`] instead of
/// blocking on a peer that will never connect.
///
/// With `opts.reconnect_attempts > 0` a router thread keeps serving
/// resync re-dials on a clone of `listener` for the life of the endpoint.
pub fn connect_mesh_opts(
    rank: usize,
    peers: &[String],
    listener: &TcpListener,
    counters: Arc<Counters>,
    health: Arc<Health>,
    opts: &TcpOptions,
) -> Result<TcpEndpoint> {
    let n = peers.len();
    assert!(rank < n, "rank {rank} outside mesh of {n}");
    let mut links: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    let mut wbuf = Vec::new();
    // Dial up first: connects land in the peers' listen backlogs, so the
    // dial/accept order across ranks cannot deadlock.
    for (j, addr) in peers.iter().enumerate().skip(rank + 1) {
        let mut s = dial_retry(addr, &health, &opts.backoff, ((rank as u64) << 32) | j as u64)
            .with_context(|| format!("rank {rank} dialing rank {j} at {addr}"))?;
        frame::write_control(
            &mut s,
            &mut wbuf,
            &format!(r#"{{"type":"hello","rank":{rank}}}"#),
        )
        .with_context(|| format!("rank {rank} hello to rank {j}"))?;
        links[j] = Some(s);
    }
    // Accept one connection from every lower rank; the hello frame says
    // which one (accept order is whatever the network delivers). The
    // listener runs non-blocking so the abort flag is honoured while
    // waiting.
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + opts.backoff.total_budget();
    let mut body = Vec::new();
    for _ in 0..rank {
        let (mut s, from) = loop {
            check_abort(&health)?;
            match listener.accept() {
                Ok(pair) => break pair,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        bail!("rank {rank} timed out waiting for lower-rank mesh peers");
                    }
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(anyhow!(e).context("accepting mesh peer")),
            }
        };
        s.set_nonblocking(false)?;
        let h = frame::read_frame(&mut s, opts.max_frame_bytes, &mut body)?
            .ok_or_else(|| anyhow!("mesh peer at {from} closed before hello"))?;
        if h.kind != frame::KIND_CONTROL {
            bail!("mesh peer at {from} sent frame kind {} before hello", h.kind);
        }
        let j = crate::util::json::Json::parse(std::str::from_utf8(&body)?)?
            .get("rank")?
            .as_usize()?;
        if j >= rank || links[j].is_some() {
            bail!("mesh hello from unexpected rank {j} (this rank: {rank})");
        }
        links[j] = Some(s);
    }
    listener.set_nonblocking(false)?;
    let redial = if opts.reconnect_attempts > 0 {
        let registry: Registry = Arc::new(Mutex::new(HashMap::new()));
        let guard = start_router(
            listener.try_clone().context("cloning mesh listener for the resync router")?,
            registry.clone(),
        )?;
        Some(RedialCtx {
            dial_addrs: peers.to_vec(),
            registry,
            guard,
        })
    } else {
        None
    };
    assemble(rank, n, links, counters, health, opts, redial)
}

fn check_abort(health: &Health) -> Result<()> {
    if health.aborted() {
        bail!(MeshError::Aborted {
            origin: health.first_dead().unwrap_or(0),
        });
    }
    Ok(())
}

/// Keep re-dialing a peer whose listener is not up yet (fresh worker
/// processes race each other to bind), sleeping the jittered exponential
/// backoff between attempts. `salt` decorrelates the jitter across
/// (rank, peer) pairs so a whole mesh does not retry in lock-step.
fn dial_retry(addr: &str, health: &Health, backoff: &BackoffConfig, salt: u64) -> Result<TcpStream> {
    let mut last = None;
    for attempt in 0..backoff.attempts {
        check_abort(health)?;
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                thread::sleep(backoff.delay(attempt, salt));
            }
        }
    }
    Err(last.expect("at least one dial attempt").into())
}

// ---------------------------------------------------------------------
// Healing links: per-pair replay state, the resync handshake, and the
// router that serves the accept side of a re-dial.
// ---------------------------------------------------------------------

/// Mutable state of one healing link, guarded by [`LinkShared::state`].
struct LinkState {
    /// The live stream, `None` while broken. Writers and the reader
    /// `try_clone` out of here under the lock.
    stream: Option<TcpStream>,
    /// Bumped on every successful (re)install; lets writers and the
    /// reader tell a heal apart from the stream they already saw break.
    generation: u64,
    /// Set once the reader has fully drained the broken stream — the
    /// received counter is final and a resync handshake may answer.
    drained: bool,
    /// Terminal: the link gave up healing.
    dead: bool,
    /// Completed heal episodes, bounded by `reconnect_attempts`.
    heals: u32,
    /// Payload frames ever sent on this link (frame sequence numbers).
    sent: u64,
    /// Payload frames delivered from this link into the inbox. TCP
    /// delivers a prefix, so this names the next frame we need.
    rcvd: u64,
    /// Encoded outbound frames `ring_start..sent`, kept for replay.
    ring: VecDeque<Vec<u8>>,
    /// Sequence number of `ring[0]`.
    ring_start: u64,
}

struct LinkShared {
    state: Mutex<LinkState>,
    cv: Condvar,
}

impl LinkShared {
    fn new(stream: TcpStream) -> Self {
        LinkShared {
            state: Mutex::new(LinkState {
                stream: Some(stream),
                generation: 1,
                drained: false,
                dead: false,
                heals: 0,
                sent: 0,
                rcvd: 0,
                ring: VecDeque::new(),
                ring_start: 0,
            }),
            cv: Condvar::new(),
        }
    }
}

enum DrainEnd {
    /// The peer said `bye` before the stream ended: a purposeful close.
    Clean,
    /// EOF, stream error, or a malformed frame with no `bye` first.
    Broken,
}

/// Decode frames off `stream` into the inbox until it ends. The exact
/// legacy reader loop; `counted` additionally bumps the link's received
/// counter for every payload frame delivered (the resync fence).
fn drain_stream(
    stream: &mut TcpStream,
    inbox: &Inbox,
    counted: Option<&LinkShared>,
    max_frame_bytes: usize,
) -> DrainEnd {
    let mut body = Vec::new();
    // `bye` received: the peer is closing on purpose; the EOF that
    // follows is not a death.
    let mut clean = false;
    loop {
        match frame::read_frame(stream, max_frame_bytes, &mut body) {
            Ok(Some(h)) => match h.kind {
                // The only control traffic on an established mesh link is
                // the close handshake.
                frame::KIND_CONTROL => clean = true,
                _ => match frame::decode_payload(h.kind, &body, Vec::new(), Vec::new()) {
                    Ok(payload) => {
                        if let Some(link) = counted {
                            link.state.lock().unwrap().rcvd += 1;
                        }
                        // An inbox at its high-water cap means the receiver
                        // has stopped draining (flood or wedge) — tear the
                        // link down rather than queue without bound.
                        if inbox
                            .push(Msg {
                                src: h.src as usize,
                                tag: h.tag,
                                payload,
                            })
                            .is_err()
                        {
                            break;
                        }
                    }
                    // A malformed frame means the stream is out of sync —
                    // unrecoverable for this connection.
                    Err(_) => break,
                },
            },
            Ok(None) => break, // EOF
            Err(_) => break,   // truncated / oversized / io error
        }
    }
    if clean {
        DrainEnd::Clean
    } else {
        DrainEnd::Broken
    }
}

/// One heal episode on the dialer side of a broken link: re-dial with
/// backoff (honouring any injected partition), run the resync handshake,
/// replay the undelivered suffix, install the new stream. Returns whether
/// the link healed; on giving up the link is dead and the peer marked.
fn heal_dial(
    rank: usize,
    peer: usize,
    addr: &str,
    link: &Arc<LinkShared>,
    counters: &Counters,
    health: &Health,
    closing: &AtomicBool,
    opts: &TcpOptions,
) -> bool {
    let give_up = |link: &Arc<LinkShared>| {
        let mut st = link.state.lock().unwrap();
        st.dead = true;
        drop(st);
        link.cv.notify_all();
        if !closing.load(Ordering::Acquire) && !health.aborted() {
            health.mark_dead(peer);
        }
        false
    };
    let my_rcvd = {
        let mut st = link.state.lock().unwrap();
        if st.heals >= opts.reconnect_attempts {
            drop(st);
            return give_up(link);
        }
        st.heals += 1;
        // The reader has fully drained the broken stream before calling
        // us, so this count is final.
        st.rcvd
    };
    let salt = ((rank as u64) << 32) | (peer as u64) | 0x4EA1_0000_0000_0000;
    let mut wbuf = Vec::new();
    for attempt in 0..opts.backoff.attempts {
        if closing.load(Ordering::Acquire) || health.aborted() || health.is_dead(peer) {
            return false;
        }
        if link.state.lock().unwrap().dead {
            return give_up(link);
        }
        if let Some(p) = &opts.link_policy {
            if p.dial_blocked(rank, peer, attempt) {
                thread::sleep(opts.backoff.delay(attempt, salt));
                continue;
            }
        }
        let mut s = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(_) => {
                thread::sleep(opts.backoff.delay(attempt, salt));
                continue;
            }
        };
        if try_resync(rank, peer, my_rcvd, &mut s, &mut wbuf, link).is_ok() {
            counters.reconnects.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        thread::sleep(opts.backoff.delay(attempt, salt));
    }
    give_up(link)
}

/// The dialer half of the resync handshake over a fresh connection:
/// report how much we received, learn how much the peer received, replay
/// our ring from there, and install the stream.
fn try_resync(
    rank: usize,
    peer: usize,
    my_rcvd: u64,
    s: &mut TcpStream,
    wbuf: &mut Vec<u8>,
    link: &Arc<LinkShared>,
) -> Result<()> {
    s.set_nodelay(true)?;
    frame::write_control(
        s,
        wbuf,
        &format!(r#"{{"type":"resync","rank":{rank},"to":{peer},"rcvd":{my_rcvd}}}"#),
    )?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut body = Vec::new();
    let h = frame::read_frame(s, 4096, &mut body)?
        .ok_or_else(|| anyhow!("peer closed during resync"))?;
    if h.kind != frame::KIND_CONTROL {
        bail!("unexpected frame kind {} in resync handshake", h.kind);
    }
    let peer_rcvd = crate::util::json::Json::parse(std::str::from_utf8(&body)?)?
        .get("rcvd")?
        .as_usize()? as u64;
    s.set_read_timeout(None)?;
    let mut st = link.state.lock().unwrap();
    if peer_rcvd < st.ring_start {
        // The peer needs frames we already evicted: the gap is
        // unrecoverable, only a full elastic re-plan can fix it.
        st.dead = true;
        drop(st);
        link.cv.notify_all();
        bail!("resync gap: peer at {peer_rcvd}, ring starts at evicted frames");
    }
    let skip = (peer_rcvd - st.ring_start) as usize;
    for f in st.ring.iter().skip(skip) {
        s.write_all(f)?;
    }
    st.stream = Some(s.try_clone()?);
    st.generation += 1;
    st.drained = false;
    drop(st);
    link.cv.notify_all();
    Ok(())
}

/// What the resync router needs to serve a re-dial for one accepted link.
#[derive(Clone)]
struct RouterEntry {
    link: Arc<LinkShared>,
    counters: Arc<Counters>,
    health: Arc<Health>,
    /// How long to wait for the old reader to finish draining.
    budget: Duration,
}

/// `(owner_rank, dialer_rank)` → the owner's accepted-side link.
type Registry = Arc<Mutex<HashMap<(usize, usize), RouterEntry>>>;

/// Keeps the resync router thread alive; dropping the last clone stops
/// it. Held by every endpoint built with reconnect enabled.
pub(crate) struct RouterGuard {
    stop: Arc<AtomicBool>,
}

impl Drop for RouterGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
    }
}

/// Everything [`assemble`] needs to make links healable: where each peer
/// can be re-dialed, and the router registry to serve inbound re-dials.
#[derive(Clone)]
struct RedialCtx {
    dial_addrs: Vec<String>,
    registry: Registry,
    guard: Arc<RouterGuard>,
}

/// Start the resync router: accept re-dial connections on `listener` and
/// hand each to a short-lived handler thread.
fn start_router(listener: TcpListener, registry: Registry) -> Result<Arc<RouterGuard>> {
    listener
        .set_nonblocking(true)
        .context("setting resync router listener non-blocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    thread::Builder::new()
        .name("tcp-mesh-router".into())
        .spawn(move || loop {
            if stop2.load(Ordering::Acquire) {
                return;
            }
            match listener.accept() {
                Ok((s, _)) => {
                    let reg = registry.clone();
                    let _ = thread::Builder::new()
                        .name("tcp-mesh-resync".into())
                        .spawn(move || {
                            let _ = handle_resync(s, reg);
                        });
                }
                Err(_) => thread::sleep(Duration::from_millis(10)),
            }
        })
        .context("spawning resync router")?;
    Ok(Arc::new(RouterGuard { stop }))
}

/// The accept half of the resync handshake: wait for the old reader to
/// drain (so our received count is final), answer it, replay our own
/// undelivered suffix, and install the new stream on the link.
fn handle_resync(mut s: TcpStream, registry: Registry) -> Result<()> {
    s.set_nodelay(true)?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut body = Vec::new();
    let h = frame::read_frame(&mut s, 4096, &mut body)?
        .ok_or_else(|| anyhow!("re-dialer closed before resync"))?;
    if h.kind != frame::KIND_CONTROL {
        bail!("unexpected frame kind {} from re-dialer", h.kind);
    }
    let j = crate::util::json::Json::parse(std::str::from_utf8(&body)?)?;
    if j.get("type")?.as_str()? != "resync" {
        bail!("unexpected control message from re-dialer");
    }
    let from = j.get("rank")?.as_usize()?;
    let to = j.get("to")?.as_usize()?;
    let peer_rcvd = j.get("rcvd")?.as_usize()? as u64;
    let entry = registry
        .lock()
        .unwrap()
        .get(&(to, from))
        .cloned()
        .ok_or_else(|| anyhow!("resync for unknown link ({to},{from})"))?;
    // Wait for the old reader to finish draining the broken stream; kick
    // it off a stream that is somehow still readable after 200ms.
    let t0 = Instant::now();
    let mut kicked = false;
    {
        let mut st = entry.link.state.lock().unwrap();
        loop {
            if st.dead {
                bail!("link ({to},{from}) already dead");
            }
            if st.drained {
                break;
            }
            if !kicked && t0.elapsed() > Duration::from_millis(200) {
                if let Some(old) = &st.stream {
                    let _ = old.shutdown(Shutdown::Both);
                }
                kicked = true;
            }
            if t0.elapsed() > entry.budget {
                bail!("old reader for link ({to},{from}) never drained");
            }
            st = entry
                .link
                .cv
                .wait_timeout(st, Duration::from_millis(20))
                .unwrap()
                .0;
        }
    }
    let my_rcvd = entry.link.state.lock().unwrap().rcvd;
    let mut wbuf = Vec::new();
    frame::write_control(&mut s, &mut wbuf, &format!(r#"{{"type":"resync-ack","rcvd":{my_rcvd}}}"#))?;
    s.set_read_timeout(None)?;
    {
        let mut st = entry.link.state.lock().unwrap();
        if peer_rcvd < st.ring_start {
            st.dead = true;
            drop(st);
            entry.link.cv.notify_all();
            entry.health.mark_dead(from);
            bail!("resync gap: re-dialer at {peer_rcvd}, ring starts past it");
        }
        let skip = (peer_rcvd - st.ring_start) as usize;
        for f in st.ring.iter().skip(skip) {
            s.write_all(f)?;
        }
        st.stream = Some(s.try_clone()?);
        st.generation += 1;
        st.drained = false;
    }
    entry.link.cv.notify_all();
    entry.counters.reconnects.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Wrap pairwise streams into an endpoint: set NODELAY (collective hops
/// are latency-bound small-to-medium writes), clone each stream for its
/// reader thread, and start the readers. With reconnect enabled, links
/// become healing links instead: accepted-side links register with the
/// resync router, dialer-side links know where to re-dial.
fn assemble(
    rank: usize,
    n: usize,
    links: Vec<Option<TcpStream>>,
    counters: Arc<Counters>,
    health: Arc<Health>,
    opts: &TcpOptions,
    redial: Option<RedialCtx>,
) -> Result<TcpEndpoint> {
    let inbox = Arc::new(Inbox::default());
    let closing = Arc::new(AtomicBool::new(false));
    let mut writers = Vec::with_capacity(n);
    let mut readers = Vec::new();
    let healing = opts.reconnect_attempts > 0;
    for (peer, link) in links.into_iter().enumerate() {
        match link {
            Some(s) => {
                s.set_nodelay(true)?;
                if healing {
                    let ctx = redial
                        .as_ref()
                        .expect("reconnect-enabled mesh needs a redial context");
                    let shared = Arc::new(LinkShared::new(s));
                    // The lower rank of a pair dialed the original
                    // connection and re-dials on a break; the higher rank
                    // accepted it and lets the router re-install.
                    if peer < rank {
                        ctx.registry.lock().unwrap().insert(
                            (rank, peer),
                            RouterEntry {
                                link: shared.clone(),
                                counters: counters.clone(),
                                health: health.clone(),
                                budget: opts.backoff.total_budget() + Duration::from_secs(2),
                            },
                        );
                    }
                    let dial_addr = if peer > rank {
                        Some(ctx.dial_addrs[peer].clone())
                    } else {
                        None
                    };
                    readers.push(spawn_healing_reader(
                        rank,
                        peer,
                        shared.clone(),
                        dial_addr,
                        inbox.clone(),
                        counters.clone(),
                        health.clone(),
                        closing.clone(),
                        opts.clone(),
                    ));
                    writers.push(Some(PeerLink::Healing {
                        shared,
                        cached: None,
                    }));
                } else {
                    let reader_stream = s.try_clone()?;
                    readers.push(spawn_reader(
                        rank,
                        peer,
                        reader_stream,
                        inbox.clone(),
                        health.clone(),
                        closing.clone(),
                        opts.max_frame_bytes,
                    ));
                    writers.push(Some(PeerLink::Plain(s)));
                }
            }
            None => writers.push(None),
        }
    }
    Ok(TcpEndpoint {
        core: Core::new(rank, n, inbox, counters, health),
        writers,
        wbuf: Vec::new(),
        readers,
        closing,
        opts: opts.clone(),
        _router: redial.map(|c| c.guard),
    })
}

/// One reader thread per peer stream: decode frames into the shared
/// inbox; translate an unclean close into `mark_dead(peer)`.
fn spawn_reader(
    rank: usize,
    peer: usize,
    mut stream: TcpStream,
    inbox: Arc<Inbox>,
    health: Arc<Health>,
    closing: Arc<AtomicBool>,
    max_frame_bytes: usize,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("tcp-mesh-r{rank}p{peer}"))
        .spawn(move || {
            let end = drain_stream(&mut stream, &inbox, None, max_frame_bytes);
            if matches!(end, DrainEnd::Broken)
                && !closing.load(Ordering::Acquire)
                && !health.is_dead(peer)
            {
                health.mark_dead(peer);
            }
        })
        .expect("spawning tcp mesh reader")
}

/// The reader thread of a healing link: drain the current stream, and on
/// an unclean break either re-dial (dialer side) or wait for the router
/// to install the peer's re-dial (acceptor side) — declaring the peer
/// dead only once the reconnect budget is spent.
#[allow(clippy::too_many_arguments)]
fn spawn_healing_reader(
    rank: usize,
    peer: usize,
    link: Arc<LinkShared>,
    dial_addr: Option<String>,
    inbox: Arc<Inbox>,
    counters: Arc<Counters>,
    health: Arc<Health>,
    closing: Arc<AtomicBool>,
    opts: TcpOptions,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("tcp-mesh-r{rank}p{peer}"))
        .spawn(move || {
            let mut last_gen = 0u64;
            loop {
                // Obtain the current stream (or give up waiting for one).
                let wait_start = Instant::now();
                let repair_deadline = opts.backoff.total_budget() + Duration::from_secs(2);
                let (mut stream, gen) = {
                    let mut st = link.state.lock().unwrap();
                    loop {
                        if closing.load(Ordering::Acquire) || st.dead || health.is_dead(peer) {
                            return;
                        }
                        if st.generation > last_gen && !st.drained {
                            if let Some(s) = &st.stream {
                                match s.try_clone() {
                                    Ok(c) => break (c, st.generation),
                                    Err(_) => {
                                        st.drained = true;
                                        st.stream = None;
                                        link.cv.notify_all();
                                    }
                                }
                            }
                        }
                        if last_gen > 0 && wait_start.elapsed() > repair_deadline {
                            st.dead = true;
                            drop(st);
                            link.cv.notify_all();
                            if !closing.load(Ordering::Acquire) && !health.aborted() {
                                health.mark_dead(peer);
                            }
                            return;
                        }
                        st = link.cv.wait_timeout(st, Duration::from_millis(50)).unwrap().0;
                    }
                };
                last_gen = gen;
                let end = drain_stream(&mut stream, &inbox, Some(&link), opts.max_frame_bytes);
                {
                    let mut st = link.state.lock().unwrap();
                    if st.generation == gen {
                        st.drained = true;
                        st.stream = None;
                    }
                }
                link.cv.notify_all();
                match end {
                    DrainEnd::Clean => return,
                    DrainEnd::Broken => {
                        if closing.load(Ordering::Acquire)
                            || health.aborted()
                            || health.is_dead(peer)
                        {
                            return;
                        }
                        if let Some(addr) = &dial_addr {
                            if !heal_dial(
                                rank, peer, addr, &link, &counters, &health, &closing, &opts,
                            ) {
                                return;
                            }
                            // Healed: loop picks up the new generation.
                        }
                        // Acceptor side: loop back and wait (bounded by
                        // `repair_deadline`) for the router to install
                        // the peer's re-dial.
                    }
                }
            }
        })
        .expect("spawning tcp mesh reader")
}

/// A writer's view of one peer connection.
enum PeerLink {
    /// Legacy: the stream is the link; a break is a death.
    Plain(TcpStream),
    /// Reconnect-enabled: replayable, seq-fenced, re-dialable. `cached`
    /// is a generation-stamped clone of the live stream so the hot send
    /// path does not `try_clone` per frame.
    Healing {
        shared: Arc<LinkShared>,
        cached: Option<(u64, TcpStream)>,
    },
}

/// One rank's socket-backed view of the mesh. Same [`Transport`] surface
/// as the in-memory [`Endpoint`](super::Endpoint): `recv` runs the shared
/// matching/health/deadline loop over the inbox the reader threads feed,
/// and `send` frames the payload into the peer's stream (recycling the
/// payload storage into this endpoint's freelist, so the high-rate
/// bucketed pipeline reuses buffers on the socket path too).
pub struct TcpEndpoint {
    core: Core,
    /// writers[r] = the link to rank `r` (`None` for this rank itself).
    writers: Vec<Option<PeerLink>>,
    /// Reusable frame-serialization buffer.
    wbuf: Vec<u8>,
    readers: Vec<thread::JoinHandle<()>>,
    /// Tells this endpoint's readers that the sockets are being shut down
    /// on purpose, so the EOF they see is not a peer death.
    closing: Arc<AtomicBool>,
    opts: TcpOptions,
    /// Keeps the resync router alive while any reconnect-enabled
    /// endpoint lives.
    _router: Option<Arc<RouterGuard>>,
}

impl TcpEndpoint {
    pub fn rank(&self) -> usize {
        self.core.rank
    }

    pub fn world_size(&self) -> usize {
        self.core.n
    }

    pub fn counters(&self) -> &Counters {
        &self.core.counters
    }

    pub fn counters_arc(&self) -> Arc<Counters> {
        self.core.counters.clone()
    }

    pub fn health(&self) -> &Health {
        &self.core.health
    }

    pub fn health_arc(&self) -> Arc<Health> {
        self.core.health.clone()
    }

    pub fn heartbeat(&self) {
        self.core.health.beat(self.core.rank);
    }

    pub fn mark_dead(&self, rank: usize) {
        self.core.health.mark_dead(rank);
    }

    pub fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.core.recv_deadline = d;
    }

    fn send_impl(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        self.core.check_send(dst)?;
        if dst >= self.core.n {
            bail!("send to out-of-range rank {dst} (n={})", self.core.n);
        }
        let bytes = payload.wire_bytes();
        if dst == self.core.rank {
            // Self-edge: loop back through the inbox like the in-memory
            // mesh (no socket exists to ourselves).
            self.core
                .inbox
                .push(Msg { src: dst, tag, payload })
                .map_err(|e| anyhow!(e).context("self-send"))?;
            self.core.note_sent(tag, bytes);
            return Ok(());
        }
        frame::encode_payload_frame(
            &mut self.wbuf,
            self.core.rank as u32,
            dst as u32,
            tag,
            &payload,
        );
        if self.wbuf.len() > self.opts.max_frame_bytes + 4 {
            return Err(anyhow::Error::new(MeshError::FrameTooLarge {
                len: self.wbuf.len().saturating_sub(4),
                max: self.opts.max_frame_bytes,
            }))
            .with_context(|| {
                format!(
                    "payload of {} wire bytes exceeds max_frame_bytes {} (raise \
                     [transport] max_frame_bytes or shrink bucket_bytes)",
                    bytes, self.opts.max_frame_bytes
                )
            });
        }
        match self
            .writers
            .get_mut(dst)
            .and_then(|w| w.as_mut())
            .expect("pairwise mesh link missing")
        {
            PeerLink::Plain(stream) => {
                stream.write_all(&self.wbuf).with_context(|| {
                    format!("rank {} tcp send to {dst} tag {tag}", self.core.rank)
                })?;
            }
            PeerLink::Healing { shared, cached } => {
                send_healing(
                    self.core.rank,
                    dst,
                    tag,
                    shared,
                    cached,
                    &self.wbuf,
                    &self.opts,
                    &self.core.health,
                )?;
            }
        }
        self.core.note_sent(tag, bytes);
        // The frame now carries the bytes; the payload storage is free.
        self.core.scratch.recycle(payload);
        Ok(())
    }
}

/// Send one encoded frame on a healing link. The frame is numbered and
/// pushed into the replay ring *before* any write: even a write the OS
/// accepts but the network loses is covered, because replay is driven by
/// the receiver's delivered count, never by local write success. On a
/// broken stream the sender parks until the link heals (the replay then
/// carries this frame) or the reconnect budget runs out.
#[allow(clippy::too_many_arguments)]
fn send_healing(
    rank: usize,
    dst: usize,
    tag: u64,
    shared: &Arc<LinkShared>,
    cached: &mut Option<(u64, TcpStream)>,
    wbuf: &[u8],
    opts: &TcpOptions,
    health: &Health,
) -> Result<()> {
    let peer_dead = |rank: usize, dst: usize, tag: u64| {
        anyhow::Error::new(MeshError::PeerDead { rank: dst })
            .context(format!("rank {rank} tcp send to {dst} tag {tag}"))
    };
    let gen = {
        let mut st = shared.state.lock().unwrap();
        if st.dead {
            return Err(peer_dead(rank, dst, tag));
        }
        let seq = st.sent;
        if let Some(p) = &opts.link_policy {
            if p.reset_now(rank, dst, seq) {
                if let Some(s) = &st.stream {
                    let _ = s.shutdown(Shutdown::Both);
                }
            }
        }
        st.ring.push_back(wbuf.to_vec());
        st.sent += 1;
        while st.ring.len() > opts.resync_window {
            st.ring.pop_front();
            st.ring_start += 1;
        }
        // Refresh the cached writer clone under the same lock that read
        // the generation: a clone taken later could silently be a healed
        // stream whose replay already carried this frame.
        let gen = st.generation;
        if cached.as_ref().map(|(g, _)| *g) != Some(gen) {
            *cached = match st.stream.as_ref().map(|s| s.try_clone()) {
                Some(Ok(c)) => Some((gen, c)),
                _ => None,
            };
        }
        gen
    };
    let wrote = match cached {
        Some((g, s)) if *g == gen => s.write_all(wbuf).is_ok(),
        _ => false,
    };
    if wrote {
        return Ok(());
    }
    // The stream is broken (or mid-heal). Force the reader off it so the
    // heal can start, then park until the link heals — the frame is in
    // the ring, so the replay delivers it — or the link dies.
    {
        let st = shared.state.lock().unwrap();
        if st.generation == gen {
            if let Some(s) = &st.stream {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
    shared.cv.notify_all();
    let deadline = Instant::now() + opts.backoff.total_budget() + Duration::from_secs(5);
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.generation > gen && st.stream.is_some() && !st.drained {
            return Ok(());
        }
        if st.dead || health.is_dead(dst) {
            return Err(peer_dead(rank, dst, tag));
        }
        if health.aborted() {
            return Err(anyhow::Error::new(MeshError::Aborted {
                origin: health.first_dead().unwrap_or(0),
            })
            .context(format!("rank {rank} tcp send to {dst} tag {tag}")));
        }
        if Instant::now() > deadline {
            st.dead = true;
            drop(st);
            shared.cv.notify_all();
            health.mark_dead(dst);
            return Err(peer_dead(rank, dst, tag));
        }
        st = shared.cv.wait_timeout(st, Duration::from_millis(50)).unwrap().0;
    }
}

impl Transport for TcpEndpoint {
    fn rank(&self) -> usize {
        self.core.rank
    }

    fn world_size(&self) -> usize {
        self.core.n
    }

    fn counters(&self) -> &Counters {
        &self.core.counters
    }

    fn counters_arc(&self) -> Arc<Counters> {
        self.core.counters.clone()
    }

    fn health(&self) -> &Health {
        &self.core.health
    }

    fn health_arc(&self) -> Arc<Health> {
        self.core.health.clone()
    }

    fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.core.recv_deadline = d;
    }

    fn send(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        self.send_impl(dst, tag, payload)
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        self.core.recv_match(src, tag)
    }

    fn pending_messages(&self) -> usize {
        self.core.pending_messages()
    }

    fn scratch(&self) -> &Scratch {
        &self.core.scratch
    }

    fn scratch_mut(&mut self) -> &mut Scratch {
        &mut self.core.scratch
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        self.closing.store(true, Ordering::Release);
        // A rank that knows itself dead must drop its sockets *cold*: the
        // missing `bye` is what tells every peer's reader this was a
        // death, not a clean close.
        let dying = self.core.health.is_dead(self.core.rank);
        for (peer, link) in self.writers.iter_mut().enumerate() {
            match link {
                Some(PeerLink::Plain(s)) => {
                    if !dying {
                        frame::encode_frame(
                            &mut self.wbuf,
                            frame::KIND_CONTROL,
                            self.core.rank as u32,
                            peer as u32,
                            0,
                            br#"{"type":"bye"}"#,
                        );
                        let _ = s.write_all(&self.wbuf);
                    }
                    let _ = s.shutdown(Shutdown::Both);
                }
                Some(PeerLink::Healing { shared, .. }) => {
                    let st = shared.state.lock().unwrap();
                    if let Some(s) = &st.stream {
                        if !dying {
                            frame::encode_frame(
                                &mut self.wbuf,
                                frame::KIND_CONTROL,
                                self.core.rank as u32,
                                peer as u32,
                                0,
                                br#"{"type":"bye"}"#,
                            );
                            let mut w: &TcpStream = s;
                            let _ = w.write_all(&self.wbuf);
                        }
                        let _ = s.shutdown(Shutdown::Both);
                    }
                    drop(st);
                    shared.cv.notify_all();
                }
                None => {}
            }
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for TcpEndpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TcpEndpoint")
            .field("rank", &self.core.rank)
            .field("n", &self.core.n)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::MeshError;
    use super::*;
    use std::time::Instant;

    fn t<T: Transport>(ep: &mut T) -> &mut dyn Transport {
        ep
    }

    #[test]
    fn loopback_point_to_point_and_tag_matching() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        t(&mut a).send_f32(1, 1, &[1.0]).unwrap();
        t(&mut a).send_f32(1, 2, &[2.0]).unwrap();
        t(&mut a).send_f16(1, 1, vec![0x3C00]).unwrap();
        // out-of-order receive parks the earlier tag-1 messages
        assert_eq!(t(&mut b).recv_f32(0, 2).unwrap(), vec![2.0]);
        assert_eq!(t(&mut b).recv_f32(0, 1).unwrap(), vec![1.0]);
        assert_eq!(t(&mut b).recv_f16(0, 1).unwrap(), vec![0x3C00]);
        assert_eq!(b.pending_messages(), 0);
        // logical payload bytes only: 4 + 4 + 2 on each side of the wire
        let (sent, recvd, msgs) = a.counters().snapshot();
        assert_eq!((sent, recvd, msgs), (10, 10, 3));
    }

    #[test]
    fn loopback_self_send_round_trips() {
        let mut eps = TcpMesh::loopback(2).unwrap();
        let mut a = eps.remove(0);
        t(&mut a).send_f32(0, 5, &[4.0, 5.0]).unwrap();
        assert_eq!(t(&mut a).recv_f32(0, 5).unwrap(), vec![4.0, 5.0]);
    }

    /// Two "processes": separate Health/Counters per endpoint, linked by
    /// `connect_mesh`. A clean drop says `bye`, so no one is marked dead.
    #[test]
    fn clean_drop_is_not_a_death() {
        let (e0, e1) = process_pair();
        let h1 = e1.health_arc();
        drop(e0);
        // e1's reader sees bye + EOF and exits without marking rank 0 dead
        let t0 = Instant::now();
        while h1.first_dead().is_none() && t0.elapsed() < Duration::from_millis(300) {
            thread::sleep(Duration::from_millis(10));
        }
        assert!(!h1.is_dead(0), "clean close must not look like a death");
        drop(e1);
    }

    /// A socket dropped *without* `bye` — what the kernel does when a
    /// worker process dies — marks the peer dead and unwinds blocked
    /// receivers in bounded time.
    #[test]
    fn socket_drop_without_bye_marks_peer_dead() {
        let (e0, mut e1) = process_pair();
        // Rank 0 "dies": knowing itself dead suppresses the bye.
        e0.mark_dead(0);
        let t0 = Instant::now();
        drop(e0);
        let err = t(&mut e1).recv_f32(0, 0).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "recv did not unwind fast");
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 0 })
        );
        assert!(e1.health().is_dead(0));
    }

    #[test]
    fn oversized_send_is_a_clean_error() {
        let mut eps = TcpMesh::loopback_with(2, 64).unwrap();
        let mut a = eps.remove(0);
        let err = t(&mut a).send_f32(1, 0, &[0.0; 100]).unwrap_err();
        assert!(format!("{err:#}").contains("max_frame_bytes"), "{err:#}");
        assert!(matches!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::FrameTooLarge { .. })
        ));
    }

    /// An injected connection reset on an established link heals through
    /// the resync handshake: every frame arrives exactly once and in
    /// order, nobody is marked dead, and the reconnect counter records
    /// the repair.
    #[test]
    fn injected_reset_heals_without_death_and_counts_reconnect() {
        let policy = Arc::new(LinkPolicy::default().with_reset(0, 1, 1));
        let opts = TcpOptions {
            reconnect_attempts: 2,
            link_policy: Some(policy.clone()),
            backoff: BackoffConfig {
                base: Duration::from_millis(10),
                max: Duration::from_millis(80),
                attempts: 8,
                jitter: 0.0,
            },
            ..TcpOptions::default()
        };
        let mut eps = TcpMesh::loopback_opts(2, opts).unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // Frame seq 0 flows normally; seq 1 trips the reset and rides the
        // replay; seq 2 uses the healed stream.
        t(&mut a).send_f32(1, 1, &[1.0]).unwrap();
        t(&mut a).send_f32(1, 2, &[2.0, 3.0]).unwrap();
        t(&mut a).send_f32(1, 3, &[4.0]).unwrap();
        t(&mut b).set_recv_deadline(Some(Duration::from_secs(20)));
        assert_eq!(t(&mut b).recv_f32(0, 1).unwrap(), vec![1.0]);
        assert_eq!(t(&mut b).recv_f32(0, 2).unwrap(), vec![2.0, 3.0]);
        assert_eq!(t(&mut b).recv_f32(0, 3).unwrap(), vec![4.0]);
        assert_eq!(b.pending_messages(), 0);
        assert!(a.health().first_dead().is_none(), "heal must not kill anyone");
        assert!(a.counters().reconnects_seen() >= 1);
        assert_eq!(policy.snapshot().0, 1, "exactly one reset fires");
        drop(a);
        drop(b);
    }

    /// When every re-dial is blocked (a partition that outlives the
    /// budget), the link gives up in bounded time and surfaces the
    /// ordinary typed death — reconnect must delay failure, not hide it.
    #[test]
    fn reconnect_attempts_exhausted_is_a_death() {
        let policy = Arc::new(
            LinkPolicy::default()
                .with_reset(0, 1, 0)
                .with_partition(0, 1, u32::MAX),
        );
        let opts = TcpOptions {
            reconnect_attempts: 1,
            link_policy: Some(policy.clone()),
            backoff: BackoffConfig {
                base: Duration::from_millis(5),
                max: Duration::from_millis(20),
                attempts: 4,
                jitter: 0.0,
            },
            ..TcpOptions::default()
        };
        let mut eps = TcpMesh::loopback_opts(2, opts).unwrap();
        let _b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let t0 = Instant::now();
        let err = t(&mut a).send_f32(1, 7, &[1.0]).unwrap_err();
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "exhaustion must be bounded by the backoff budget"
        );
        assert!(
            matches!(
                err.downcast_ref::<MeshError>(),
                Some(&MeshError::PeerDead { rank: 1 }) | Some(&MeshError::Aborted { .. })
            ),
            "{err:#}"
        );
        assert!(policy.snapshot().1 > 0, "the partition blocked re-dials");
    }

    /// Build a 2-rank mesh the way two worker processes would: one
    /// listener and one Health/Counters pair per endpoint.
    fn process_pair() -> (TcpEndpoint, TcpEndpoint) {
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs = vec![
            l0.local_addr().unwrap().to_string(),
            l1.local_addr().unwrap().to_string(),
        ];
        let a0 = addrs.clone();
        let h = thread::spawn(move || {
            connect_mesh(
                0,
                &a0,
                &l0,
                Arc::new(Counters::default()),
                Arc::new(Health::new(2)),
                DEFAULT_MAX_FRAME_BYTES,
            )
            .unwrap()
        });
        let e1 = connect_mesh(
            1,
            &addrs,
            &l1,
            Arc::new(Counters::default()),
            Arc::new(Health::new(2)),
            DEFAULT_MAX_FRAME_BYTES,
        )
        .unwrap();
        (h.join().unwrap(), e1)
    }
}
