//! The in-memory transport: `n` fully-connected [`Endpoint`]s inside one
//! process, one condvar [`Inbox`](super::Inbox) per rank.
//!
//! This is the **default** transport ([`TrainConfig::transport`]
//! `mode = "memory"`) and the control implementation for the socket one:
//! same [`Transport`] surface, same counters, same health semantics, zero
//! serialization. Sends push straight into the destination inbox and
//! never block; a blocked `recv` parks on the inbox condvar (no sleep
//! polling) and is woken by arrivals, peer death, or its deadline.
//!
//! [`TrainConfig::transport`]: crate::config::TrainConfig

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::{Core, Counters, Health, Inbox, Msg, Payload, Scratch, Transport};

/// Factory for a fully-connected in-memory mesh of `n` endpoints.
pub struct Mesh;

impl Mesh {
    /// Build `n` endpoints sharing one counter block and one health table.
    pub fn new(n: usize) -> Vec<Endpoint> {
        assert!(n > 0, "mesh needs at least one rank");
        let counters = Arc::new(Counters::default());
        let health = Arc::new(Health::new(n));
        let inboxes: Vec<Arc<Inbox>> = (0..n).map(|_| Arc::new(Inbox::default())).collect();
        (0..n)
            .map(|rank| Endpoint {
                core: Core::new(
                    rank,
                    n,
                    inboxes[rank].clone(),
                    counters.clone(),
                    health.clone(),
                ),
                peers: inboxes.clone(),
            })
            .collect()
    }
}

/// One rank's view of the in-memory mesh (owned by that rank's worker
/// thread). The inherent methods mirror the [`Transport`] trait so
/// existing concrete-typed callers keep working without importing it.
pub struct Endpoint {
    core: Core,
    /// Every rank's inbox (including this rank's own, so self-sends work
    /// like any other edge).
    peers: Vec<Arc<Inbox>>,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.core.rank
    }

    pub fn world_size(&self) -> usize {
        self.core.n
    }

    pub fn counters(&self) -> &Counters {
        &self.core.counters
    }

    /// Shared counter block (snapshot it *after* joining all rank threads —
    /// per-thread snapshots race with peers still in flight).
    pub fn counters_arc(&self) -> Arc<Counters> {
        self.core.counters.clone()
    }

    /// Shared health table of this endpoint's mesh (the coordinator's
    /// heartbeat monitor scans it; tests use it to kill ranks).
    pub fn health(&self) -> &Health {
        &self.core.health
    }

    pub fn health_arc(&self) -> Arc<Health> {
        self.core.health.clone()
    }

    /// Tick this rank's heartbeat (also ticked automatically while blocked
    /// in `recv` — call it once per step so compute-heavy gaps still beat).
    pub fn heartbeat(&self) {
        self.core.health.beat(self.core.rank);
    }

    /// Declare a peer (or this rank itself) dead; aborts the whole mesh.
    pub fn mark_dead(&self, rank: usize) {
        self.core.health.mark_dead(rank);
    }

    /// Bound every subsequent blocking `recv` to `d` of wall-clock wait;
    /// on expiry the awaited peer is marked dead and the receive fails
    /// with [`MeshError::PeerDead`](super::MeshError::PeerDead). `None`
    /// removes the bound.
    pub fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.core.recv_deadline = d;
    }

    /// Send `payload` to `dst` under `tag`. Never blocks; fails fast when
    /// `dst` is already marked dead, the mesh is aborting, or the
    /// destination inbox is at its high-water cap
    /// ([`MeshError::InboxOverflow`](super::MeshError::InboxOverflow)).
    pub fn send(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        self.core.check_send(dst)?;
        let bytes = payload.wire_bytes();
        self.peers
            .get(dst)
            .ok_or_else(|| anyhow!("send to out-of-range rank {dst} (n={})", self.core.n))?
            .push(Msg { src: self.core.rank, tag, payload })
            .map_err(|e| {
                anyhow!(e).context(format!(
                    "rank {} send to {dst} tag {tag}",
                    self.core.rank
                ))
            })?;
        self.core.note_sent(tag, bytes);
        Ok(())
    }

    /// Copy `data` into a freelist-backed buffer and send it (no per-hop
    /// allocation once the freelist has warmed up).
    pub fn send_f32(&mut self, dst: usize, tag: u64, data: &[f32]) -> Result<()> {
        let mut buf = self.core.scratch.alloc_f32(data.len());
        buf.extend_from_slice(data);
        self.send(dst, tag, Payload::F32(buf))
    }

    pub fn send_f16(&mut self, dst: usize, tag: u64, data: Vec<u16>) -> Result<()> {
        self.send(dst, tag, Payload::F16(data))
    }

    pub fn alloc_f32(&mut self, capacity_hint: usize) -> Vec<f32> {
        self.core.scratch.alloc_f32(capacity_hint)
    }

    pub fn alloc_f16(&mut self, len: usize) -> Vec<u16> {
        self.core.scratch.alloc_f16(len)
    }

    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        self.core.scratch.recycle_f32(v)
    }

    pub fn recycle_f16(&mut self, v: Vec<u16>) {
        self.core.scratch.recycle_f16(v)
    }

    pub fn recycle(&mut self, p: Payload) {
        self.core.scratch.recycle(p)
    }

    pub fn freelist_hits(&self) -> u64 {
        self.core.scratch.hits()
    }

    /// Blocking receive of the message matching `(src, tag)`; see
    /// [`Core::recv_match`](super::Core) for the matching, health and
    /// deadline semantics.
    pub fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        self.core.recv_match(src, tag)
    }

    /// Number of parked out-of-order messages (tests assert this drains to
    /// zero so the pending map cannot leak across a long run).
    pub fn pending_messages(&self) -> usize {
        self.core.pending_messages()
    }

    /// Receive and require an f32 payload (wire-format mismatch is a bug).
    pub fn recv_f32(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        Transport::recv_f32(self, src, tag)
    }

    /// Receive and require an f16 payload.
    pub fn recv_f16(&mut self, src: usize, tag: u64) -> Result<Vec<u16>> {
        Transport::recv_f16(self, src, tag)
    }
}

impl Transport for Endpoint {
    fn rank(&self) -> usize {
        self.core.rank
    }

    fn world_size(&self) -> usize {
        self.core.n
    }

    fn counters(&self) -> &Counters {
        &self.core.counters
    }

    fn counters_arc(&self) -> Arc<Counters> {
        self.core.counters.clone()
    }

    fn health(&self) -> &Health {
        &self.core.health
    }

    fn health_arc(&self) -> Arc<Health> {
        self.core.health.clone()
    }

    fn set_recv_deadline(&mut self, d: Option<Duration>) {
        self.core.recv_deadline = d;
    }

    fn send(&mut self, dst: usize, tag: u64, payload: Payload) -> Result<()> {
        Endpoint::send(self, dst, tag, payload)
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Payload> {
        self.core.recv_match(src, tag)
    }

    fn pending_messages(&self) -> usize {
        self.core.pending_messages()
    }

    fn scratch(&self) -> &Scratch {
        &self.core.scratch
    }

    fn scratch_mut(&mut self) -> &mut Scratch {
        &mut self.core.scratch
    }
}

impl std::fmt::Debug for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Endpoint")
            .field("rank", &self.core.rank)
            .field("n", &self.core.n)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{MeshError, FREELIST_CAP};
    use super::*;
    use std::thread;
    use std::time::Instant;

    #[test]
    fn point_to_point_round_trip() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 7, &[1.0, 2.0, 3.0]).unwrap();
        let got = b.recv_f32(0, 7).unwrap();
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 1, &[1.0]).unwrap();
        a.send_f32(1, 2, &[2.0]).unwrap();
        a.send_f32(1, 1, &[3.0]).unwrap();
        // Receive tag 2 first; tag-1 messages must stay queued in order.
        assert_eq!(b.recv_f32(0, 2).unwrap(), vec![2.0]);
        assert_eq!(b.recv_f32(0, 1).unwrap(), vec![1.0]);
        assert_eq!(b.recv_f32(0, 1).unwrap(), vec![3.0]);
    }

    #[test]
    fn byte_conservation_across_threads() {
        let n = 4;
        let eps = Mesh::new(n);
        let counters = eps[0].counters_arc();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let me = ep.rank();
                    let right = (me + 1) % 4;
                    let left = (me + 3) % 4;
                    for step in 0..10u64 {
                        ep.send_f32(right, step, &vec![me as f32; 100]).unwrap();
                        let got = ep.recv_f32(left, step).unwrap();
                        assert_eq!(got, vec![left as f32; 100]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (sent, recvd, msgs) = counters.snapshot();
        assert_eq!(sent, recvd);
        assert_eq!(sent, 4 * 10 * 100 * 4); // ranks * steps * elems * 4B
        assert_eq!(msgs, 40);
    }

    #[test]
    fn pending_queue_drains_and_entries_are_dropped() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // out-of-order burst: many messages on tags received later
        for i in 0..50u64 {
            a.send_f32(1, i % 5, &[i as f32]).unwrap();
        }
        a.send_f32(1, 99, &[99.0]).unwrap();
        // receiving tag 99 first parks all 50 burst messages
        assert_eq!(b.recv_f32(0, 99).unwrap(), vec![99.0]);
        assert_eq!(b.pending_messages(), 50);
        // drain them in FIFO order per tag
        for i in 0..50u64 {
            let tag = i % 5;
            let got = b.recv_f32(0, tag).unwrap();
            // per-tag order: the k-th receive of `tag` is message 5k+tag
            assert_eq!(got, vec![(5 * (i / 5) + tag) as f32], "tag {tag}");
        }
        // fully drained: no empty queues linger in the map
        assert_eq!(b.pending_messages(), 0);
        assert!(b.core.pending.is_empty(), "empty pending entries leaked");
    }

    #[test]
    fn f16_payload_counts_two_bytes() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f16(1, 0, vec![0x3C00; 8]).unwrap();
        let got = b.recv_f16(0, 0).unwrap();
        assert_eq!(got.len(), 8);
        let (sent, _, _) = a.counters().snapshot();
        assert_eq!(sent, 16);
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_f32(1, 0, &[1.0]).unwrap();
        assert!(b.recv_f16(0, 0).is_err());
    }

    #[test]
    fn send_out_of_range_is_error() {
        let mut eps = Mesh::new(2);
        assert!(eps[0].send_f32(5, 0, &[1.0]).is_err());
    }

    /// The freelist must never hand back a stale payload: a recycled long
    /// buffer reused for a shorter message carries exactly the new bytes —
    /// no leftover tail, no leftover length.
    #[test]
    fn freelist_never_hands_back_stale_payloads() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();

        // f32: long payload recycled on b, then b sends a short one.
        a.send_f32(1, 0, &[9.0; 64]).unwrap();
        let long = b.recv_f32(0, 0).unwrap();
        assert_eq!(long.len(), 64);
        b.recycle_f32(long);
        b.send_f32(0, 1, &[1.0, 2.0]).unwrap();
        assert!(b.freelist_hits() >= 1, "short send must hit the freelist");
        assert_eq!(a.recv_f32(1, 1).unwrap(), vec![1.0, 2.0]);

        // f16: alloc after recycling a longer buffer is exact-length and
        // zero-filled, not a truncated view of the old contents.
        a.send_f16(1, 2, vec![7u16; 50]).unwrap();
        let enc = b.recv_f16(0, 2).unwrap();
        b.recycle_f16(enc);
        let mut short = b.alloc_f16(3);
        assert_eq!(short, vec![0u16; 3]);
        short.copy_from_slice(&[1, 2, 3]);
        b.send_f16(0, 3, short).unwrap();
        assert_eq!(a.recv_f16(1, 3).unwrap(), vec![1, 2, 3]);

        // the cap bounds parked buffers
        for _ in 0..100 {
            b.recycle_f32(vec![0.0; 4]);
        }
        assert!(b.core.scratch.parked_f32() <= FREELIST_CAP);
    }

    /// The core deadlock fix: a recv blocked on a peer unwinds with
    /// `PeerDead` as soon as that peer is marked dead — no message needed.
    #[test]
    fn recv_unblocks_when_peer_is_marked_dead() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let t0 = Instant::now();
        let killer = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            a.mark_dead(0);
        });
        let err = b.recv_f32(0, 0).unwrap_err();
        killer.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "recv did not unblock fast");
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 0 })
        );
    }

    /// An abort triggered by *any* death unwinds recvs waiting on healthy
    /// peers too (victim ranks see `Aborted`, not `PeerDead`).
    #[test]
    fn abort_unblocks_recv_from_healthy_peer() {
        let eps = Mesh::new(3);
        let health = eps[0].health_arc();
        let mut ep2 = eps.into_iter().nth(2).unwrap();
        health.mark_dead(1);
        // rank 2 waits on rank 0 (healthy) — must still unwind via abort
        let err = ep2.recv_f32(0, 0).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::Aborted { origin: 1 })
        );
        assert_eq!(health.first_dead(), Some(1));
        assert_eq!(health.dead_ranks(), vec![1]);
    }

    #[test]
    fn send_to_dead_rank_fails_fast() {
        let mut eps = Mesh::new(2);
        eps[0].mark_dead(1);
        let err = eps[0].send_f16(1, 0, vec![1]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 1 })
        );
    }

    /// The recv deadline is the belt-and-braces bound: with no one marking
    /// anyone dead, an absent message still surfaces as `PeerDead` (and
    /// marks the silent peer dead for the rest of the mesh).
    #[test]
    fn recv_deadline_marks_silent_peer_dead() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        b.set_recv_deadline(Some(Duration::from_millis(30)));
        let t0 = Instant::now();
        let err = b.recv_f32(0, 7).unwrap_err();
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::PeerDead { rank: 0 })
        );
        assert!(b.health().is_dead(0));
        assert!(b.health().aborted());
    }

    /// Heartbeats: blocked receivers keep beating; a completed rank marks
    /// itself done so a monitor can tell "finished" from "hung". The
    /// condvar wait must preserve the old tick-loop guarantee that a
    /// blocked rank's beat never goes more than ~one wait slice stale.
    #[test]
    fn heartbeats_tick_while_blocked_and_done_is_sticky() {
        let mut eps = Mesh::new(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let health = a.health_arc();
        let waiter = thread::spawn(move || {
            let _ = b.recv_f32(0, 0); // unblocked by the abort below
        });
        thread::sleep(Duration::from_millis(50));
        // rank 1 is blocked in recv, but its wait loop keeps it beating
        assert!(
            health.millis_since_beat(1) < 40,
            "blocked recv must keep beating ({}ms stale)",
            health.millis_since_beat(1)
        );
        health.mark_done(0);
        assert!(health.is_done(0));
        health.mark_dead(0);
        waiter.join().unwrap();
    }

    /// Regression (bounded inboxes): a sender flooding a peer that never
    /// drains hits the high-water cap and gets the typed overflow error
    /// instead of growing the pending queue without bound.
    #[test]
    fn send_surfaces_inbox_overflow_at_the_cap() {
        use super::super::INBOX_CAP;
        let mut eps = Mesh::new(2);
        let mut a = eps.remove(0);
        for i in 0..INBOX_CAP as u64 {
            a.send_f32(1, i, &[0.0]).unwrap();
        }
        let err = a.send_f32(1, 0, &[0.0]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<MeshError>(),
            Some(&MeshError::InboxOverflow { len: INBOX_CAP, cap: INBOX_CAP })
        );
    }

    /// A self-send loops back through this rank's own inbox like any
    /// other edge (the TCP transport special-cases this identically).
    #[test]
    fn self_send_round_trips() {
        let mut eps = Mesh::new(2);
        let mut a = eps.remove(0);
        a.send_f32(0, 5, &[4.0, 5.0]).unwrap();
        assert_eq!(a.recv_f32(0, 5).unwrap(), vec![4.0, 5.0]);
    }
}
