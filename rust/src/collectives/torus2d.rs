//! 2D-Torus all-reduce — the paper's communication contribution (§2.2).
//!
//! GPUs are arranged in a logical X (horizontal) × Y (vertical) grid;
//! the all-reduce runs in three phases (paper Figure 2):
//!
//!   1. **reduce-scatter, horizontal** — each row ring-reduce-scatters the
//!      full buffer; every rank ends owning `1/X` of it, reduced across its
//!      row.
//!   2. **all-reduce, vertical** — each column ring-all-reduces *only the
//!      owned chunk* (size `n/X`), completing the reduction across rows.
//!   3. **all-gather, horizontal** — each row ring-all-gathers, so every
//!      rank ends with the fully reduced buffer.
//!
//! Per-rank step count is `2(X-1) + 2(Y-1)` with per-step payloads of
//! `n/X` and `n/(X·Y)` elements; compared to a flat ring's `2(N-1)` steps
//! this trades the latency term from `O(N)` to `O(X+Y)` while staying
//! bandwidth-optimal — and the vertical phase moves X-fold less data than
//! hierarchical all-reduce's inter-group phase (paper §2.2).
//!
//! Rank layout: `rank = y * X + x` (row-major); a *row* (fixed y) is the
//! horizontal ring, a *column* (fixed x) the vertical ring. Grid shapes for
//! the paper's cluster sizes are in `cluster::grid` (Table 4).

use anyhow::{bail, Result};

use super::primitives::{
    chunk_offsets, ring_all_gather, ring_all_reduce, ring_reduce_scatter, Wire,
};
use super::transport::Transport;
use super::Collective;

/// The paper's 2D-Torus all-reduce over an X×Y logical grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TorusAllReduce {
    /// Ranks per row (horizontal ring length).
    pub x: usize,
    /// Ranks per column (vertical ring length).
    pub y: usize,
}

impl TorusAllReduce {
    pub fn new(x: usize, y: usize) -> Self {
        assert!(x > 0 && y > 0, "grid dimensions must be positive");
        Self { x, y }
    }

    pub fn ranks(&self) -> usize {
        self.x * self.y
    }

    /// Global ranks of the row containing `rank`.
    pub fn row_group(&self, rank: usize) -> Vec<usize> {
        let row = rank / self.x;
        (0..self.x).map(|i| row * self.x + i).collect()
    }

    /// Global ranks of the column containing `rank`.
    pub fn col_group(&self, rank: usize) -> Vec<usize> {
        let col = rank % self.x;
        (0..self.y).map(|j| j * self.x + col).collect()
    }
}

impl Collective for TorusAllReduce {
    fn name(&self) -> String {
        format!("torus2d({}x{})", self.x, self.y)
    }

    fn all_reduce(
        &self,
        ep: &mut dyn Transport,
        buf: &mut [f32],
        wire: Wire,
        tag_base: u64,
    ) -> Result<()> {
        if ep.world_size() != self.ranks() {
            bail!(
                "torus {}x{} needs exactly {} ranks, mesh has {}",
                self.x,
                self.y,
                self.ranks(),
                ep.world_size()
            );
        }
        let rank = ep.rank();
        let row = self.row_group(rank);
        let col = self.col_group(rank);
        let x_pos = rank % self.x;
        let y_pos = rank / self.x;

        // Tag-space layout: the three phases use disjoint tag windows so a
        // rank's row and column traffic can never be confused. Windows are
        // packed back-to-back at their exact widths (`tag_span` is tight),
        // because the bucketed gradient pipeline stacks one whole span per
        // bucket per step — slack here multiplies across every bucket.
        let t_scatter = tag_base;
        let t_vertical = t_scatter + Self::scatter_width(self.x);
        let t_gather = t_vertical + Self::vertical_width(self.y);

        // Phase 1: horizontal reduce-scatter (paper Fig. 2, step 1).
        let owned = ring_reduce_scatter(ep, &row, x_pos, buf, wire, t_scatter)?;

        // Phase 2: vertical all-reduce of the owned chunk only (step 2).
        let offs = chunk_offsets(buf.len(), self.x);
        let chunk = &mut buf[offs[owned]..offs[owned + 1]];
        ring_all_reduce(ep, &col, y_pos, chunk, wire, t_vertical)?;

        // Phase 3: horizontal all-gather (step 3).
        ring_all_gather(ep, &row, x_pos, buf, wire, t_gather)
    }

    fn p2p_steps(&self, n_ranks: usize) -> usize {
        debug_assert_eq!(n_ranks, self.ranks());
        2 * (self.x - 1) + 2 * (self.y - 1)
    }

    /// Exact tag window: horizontal reduce-scatter (`x-1` tags) + vertical
    /// ring all-reduce (`2y-1` tags when `y > 1`) + horizontal all-gather
    /// (`x-1` tags) — `2x + 2y - 3` for a non-degenerate grid, previously
    /// over-reserved as `3x + 2y`. Clamped to 1 so adjacent windows are
    /// still distinct on a 1×1 grid (which sends nothing).
    fn tag_span(&self, _n_ranks: usize) -> u64 {
        (2 * Self::scatter_width(self.x) + Self::vertical_width(self.y)).max(1)
    }
}

impl TorusAllReduce {
    /// Tags used by a ring reduce-scatter (or all-gather) over `k` ranks:
    /// `k - 1` steps, one tag each (none for a singleton ring).
    fn scatter_width(k: usize) -> u64 {
        k.saturating_sub(1) as u64
    }

    /// Tags used by a ring all-reduce over `k` ranks: reduce-scatter at
    /// offsets `[0, k-2]` plus all-gather at `[k, 2k-2]` (the primitive
    /// offsets its gather window by `k`), so `2k - 1` tags; none for a
    /// singleton ring.
    fn vertical_width(k: usize) -> u64 {
        if k > 1 {
            (2 * k - 1) as u64
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::test_support::{check_all_reduce_matches_sum, run_collective};
    use crate::util::quickcheck::{prop_seeded, Gen};

    #[test]
    fn figure2_grid_2x2_matches_sum() {
        // The paper's worked example: 4 GPUs in a 2x2 grid.
        check_all_reduce_matches_sum(&TorusAllReduce::new(2, 2), 4, 64, Wire::F32, 1e-4);
    }

    #[test]
    fn assorted_grids_match_sum() {
        for (x, y) in [(1, 1), (1, 4), (4, 1), (2, 3), (3, 2), (4, 4), (3, 5)] {
            let t = TorusAllReduce::new(x, y);
            check_all_reduce_matches_sum(&t, x * y, 97, Wire::F32, 1e-4);
        }
    }

    #[test]
    fn fp16_wire_agreement() {
        check_all_reduce_matches_sum(&TorusAllReduce::new(3, 2), 6, 80, Wire::F16, 5e-3);
    }

    #[test]
    fn property_random_grids_and_sizes() {
        prop_seeded(0x70B1_D05E, 24, |g: &mut Gen| {
            let x = g.usize_in(1..=4);
            let y = g.usize_in(1..=4);
            let elems = g.usize_in(1..=300);
            let t = TorusAllReduce::new(x, y);
            check_all_reduce_matches_sum(&t, x * y, elems, Wire::F32, 1e-3);
        });
    }

    #[test]
    fn rejects_wrong_world_size() {
        let t = TorusAllReduce::new(2, 2);
        let mut eps = crate::collectives::transport::Mesh::new(3);
        let mut ep = eps.remove(0);
        let mut buf = vec![1.0f32; 8];
        assert!(t.all_reduce(&mut ep, &mut buf, Wire::F32, 0).is_err());
    }

    #[test]
    fn step_count_formula_table4_grids() {
        // Table 4 grids: (V, H) -> our (x=H, y=V).
        for (v, h, n) in [(32, 32, 1024), (32, 64, 2048), (34, 64, 2176),
                          (48, 72, 3456), (64, 64, 4096)] {
            let t = TorusAllReduce::new(h, v);
            assert_eq!(t.ranks(), n);
            assert_eq!(t.p2p_steps(n), 2 * (h - 1) + 2 * (v - 1));
            // always beats the flat ring's 2(N-1) for these shapes
            assert!(t.p2p_steps(n) < 2 * (n - 1));
        }
    }

    #[test]
    fn tag_span_is_tight_for_table4_grids() {
        // The declared window must be the exact packed width
        // `2(x-1) + (2y-1)` = `2x + 2y - 3` for every non-degenerate grid,
        // including the paper's Table-4 cluster shapes ((V, H) -> x=H, y=V).
        for (v, h) in [(32usize, 32usize), (32, 64), (34, 64), (48, 72), (64, 64)] {
            let t = TorusAllReduce::new(h, v);
            assert_eq!(t.tag_span(h * v), (2 * h + 2 * v - 3) as u64, "{h}x{v}");
        }
        // Degenerate rings contribute no tags at all.
        assert_eq!(TorusAllReduce::new(1, 4).tag_span(4), 7); // vertical only: 2*4-1
        assert_eq!(TorusAllReduce::new(4, 1).tag_span(4), 6); // horizontal only: 2*(4-1)
        assert_eq!(TorusAllReduce::new(1, 1).tag_span(1), 1); // clamp: no traffic
    }

    #[test]
    fn row_col_groups_are_consistent() {
        let t = TorusAllReduce::new(3, 2); // ranks 0..6, rows [0,1,2],[3,4,5]
        assert_eq!(t.row_group(4), vec![3, 4, 5]);
        assert_eq!(t.col_group(4), vec![1, 4]);
        assert_eq!(t.row_group(0), vec![0, 1, 2]);
        assert_eq!(t.col_group(0), vec![0, 3]);
    }

    #[test]
    fn vertical_phase_moves_x_times_less_data() {
        // Byte accounting: total bytes = rows phase (2(X-1)/X * n per rank)
        // + vertical phase (2(Y-1)/Y * n/X per rank) + gather.
        let (x, y) = (4usize, 2usize);
        let n_ranks = x * y;
        let elems = 96usize; // divisible by x and x*y for exact formula
        let t = TorusAllReduce::new(x, y);
        let (_, (sent, recvd, _)) = run_collective(&t, n_ranks, elems, Wire::F32);
        assert_eq!(sent, recvd);
        let per_rank_elems =
            // phase 1: (x-1) sends of n/x
            (x - 1) * (elems / x)
            // phase 2: 2(y-1) sends of n/(x*y)
            + 2 * (y - 1) * (elems / (x * y))
            // phase 3: (x-1) sends of n/x
            + (x - 1) * (elems / x);
        assert_eq!(sent, (n_ranks * per_rank_elems * 4) as u64);
    }
}
