//! Ring-schedule building blocks: reduce-scatter, all-gather, all-reduce.
//!
//! All three higher-level collectives (flat ring, hierarchical, 2D-torus)
//! are compositions of these primitives over different *groups* — subsets of
//! global ranks (a row, a column, a node, or the whole world). Each primitive
//! takes the group as a slice of global ranks plus the caller's position in
//! it, so the same code runs a horizontal row ring and a vertical column
//! ring (paper Figure 2).
//!
//! Wire precision is a parameter ([`Wire`]): the paper sends gradients as
//! FP16 and BN statistics as FP32 (§3.2). With `Wire::F16` every hop
//! quantises to binary16 on send and widens to f32 before accumulating —
//! the same numerics as an FP16 NCCL ring — so precision effects are
//! faithfully modelled, while accumulator state stays f32.

use anyhow::Result;

use super::transport::{frame, Transport};
use crate::util::half;

/// Wire precision for a collective (paper §3.2 mixed-precision policy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    F32,
    F16,
}

impl Wire {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Wire::F32 => 4,
            Wire::F16 => 2,
        }
    }
}

/// Even chunk boundaries: `k+1` offsets over `n` elements, remainder spread
/// over the leading chunks (chunk sizes differ by at most 1).
pub fn chunk_offsets(n: usize, k: usize) -> Vec<usize> {
    assert!(k > 0);
    let base = n / k;
    let rem = n % k;
    let mut offs = Vec::with_capacity(k + 1);
    let mut acc = 0;
    offs.push(0);
    for i in 0..k {
        acc += base + usize::from(i < rem);
        offs.push(acc);
    }
    offs
}

/// Send one chunk. Wire scratch comes from the endpoint's freelist
/// (`send_f32` internally; `alloc_f16` for the encode buffer here) and the
/// FP16 quantisation goes through the shared [`frame`] codec, so a steady
/// ring schedule allocates nothing per hop after warmup and both
/// transports put bit-identical payloads on the wire.
fn send_chunk(
    ep: &mut dyn Transport,
    dst: usize,
    tag: u64,
    chunk: &[f32],
    wire: Wire,
) -> Result<()> {
    match wire {
        Wire::F32 => ep.send_f32(dst, tag, chunk),
        Wire::F16 => {
            let mut enc = ep.alloc_f16(chunk.len());
            frame::encode_f16(chunk, &mut enc);
            ep.send_f16(dst, tag, enc)
        }
    }
}

fn recv_chunk(
    ep: &mut dyn Transport,
    src: usize,
    tag: u64,
    out: &mut Vec<f32>,
    wire: Wire,
) -> Result<()> {
    match wire {
        Wire::F32 => {
            // Zero-copy: take the payload as `out` and recycle whatever
            // buffer the caller was holding.
            let v = ep.recv_f32(src, tag)?;
            ep.recycle_f32(std::mem::replace(out, v));
        }
        Wire::F16 => {
            let enc = ep.recv_f16(src, tag)?;
            frame::decode_f16(&enc, out);
            ep.recycle_f16(enc);
        }
    }
    Ok(())
}

/// Receive a chunk and accumulate it into `dst` (reduce-scatter hop),
/// fusing decode+add+requantise on the FP16 path (single pass, no
/// intermediate buffer). The consumed payload's storage is recycled into
/// the endpoint freelist for the next send.
fn recv_accumulate(
    ep: &mut dyn Transport,
    src: usize,
    tag: u64,
    dst: &mut [f32],
    wire: Wire,
) -> Result<()> {
    match wire {
        Wire::F32 => {
            let incoming = ep.recv_f32(src, tag)?;
            debug_assert_eq!(dst.len(), incoming.len());
            for (d, s) in dst.iter_mut().zip(&incoming) {
                *d += s;
            }
            ep.recycle_f32(incoming);
        }
        Wire::F16 => {
            let enc = ep.recv_f16(src, tag)?;
            debug_assert_eq!(dst.len(), enc.len());
            frame::accumulate_f16(dst, &enc);
            ep.recycle_f16(enc);
        }
    }
    Ok(())
}

/// Ring reduce-scatter over `group`.
///
/// On entry every rank holds a full local `buf`; after `k-1` steps the rank
/// at position `my_pos` holds the fully reduced (summed) chunk
/// `(my_pos + 1) % k` — other regions of `buf` hold partial sums and must be
/// treated as scratch. Returns the owned chunk index.
pub fn ring_reduce_scatter(
    ep: &mut dyn Transport,
    group: &[usize],
    my_pos: usize,
    buf: &mut [f32],
    wire: Wire,
    tag_base: u64,
) -> Result<usize> {
    let k = group.len();
    debug_assert_eq!(group[my_pos], ep.rank());
    if k == 1 {
        return Ok(0);
    }
    let offs = chunk_offsets(buf.len(), k);
    let right = group[(my_pos + 1) % k];
    let left = group[(my_pos + k - 1) % k];
    for step in 0..k - 1 {
        let send_idx = (my_pos + k - step) % k;
        let recv_idx = (my_pos + 2 * k - step - 1) % k;
        let tag = tag_base + step as u64;
        send_chunk(ep, right, tag, &buf[offs[send_idx]..offs[send_idx + 1]], wire)?;
        // Accumulate in place. On the FP16 wire the buffer itself is fp16
        // (as in an FP16 NCCL ring): the partial is re-quantised per hop;
        // decode+add+requantise run fused in a single pass.
        recv_accumulate(
            ep,
            left,
            tag,
            &mut buf[offs[recv_idx]..offs[recv_idx + 1]],
            wire,
        )?;
    }
    Ok((my_pos + 1) % k)
}

/// Ring all-gather over `group`.
///
/// On entry the rank at position `my_pos` holds the final value of chunk
/// `(my_pos + 1) % k` (the reduce-scatter ownership convention); after `k-1`
/// steps every rank holds all final chunks.
pub fn ring_all_gather(
    ep: &mut dyn Transport,
    group: &[usize],
    my_pos: usize,
    buf: &mut [f32],
    wire: Wire,
    tag_base: u64,
) -> Result<()> {
    let k = group.len();
    debug_assert_eq!(group[my_pos], ep.rank());
    if k == 1 {
        return Ok(());
    }
    let offs = chunk_offsets(buf.len(), k);
    if wire == Wire::F16 {
        // The owner's copy of its chunk lives in the fp16 buffer too; it
        // must match what every peer receives, bit for bit.
        let own = (my_pos + 1) % k;
        for v in &mut buf[offs[own]..offs[own + 1]] {
            *v = half::quantize_f16(*v);
        }
    }
    let right = group[(my_pos + 1) % k];
    let left = group[(my_pos + k - 1) % k];
    let mut incoming: Vec<f32> = ep.alloc_f32(offs[1]);
    for step in 0..k - 1 {
        let send_idx = (my_pos + 2 * k - step + 1) % k;
        let recv_idx = (my_pos + 2 * k - step) % k;
        let tag = tag_base + step as u64;
        send_chunk(ep, right, tag, &buf[offs[send_idx]..offs[send_idx + 1]], wire)?;
        recv_chunk(ep, left, tag, &mut incoming, wire)?;
        let dst = &mut buf[offs[recv_idx]..offs[recv_idx + 1]];
        debug_assert_eq!(dst.len(), incoming.len());
        dst.copy_from_slice(&incoming);
    }
    ep.recycle_f32(incoming);
    Ok(())
}

/// Ring all-reduce (sum) over `group`: reduce-scatter followed by all-gather.
/// `2(k-1)` peer-to-peer steps, each moving `n/k` elements — the baseline
/// cost model the paper compares against (its ref. [14]).
pub fn ring_all_reduce(
    ep: &mut dyn Transport,
    group: &[usize],
    my_pos: usize,
    buf: &mut [f32],
    wire: Wire,
    tag_base: u64,
) -> Result<()> {
    ring_reduce_scatter(ep, group, my_pos, buf, wire, tag_base)?;
    ring_all_gather(ep, group, my_pos, buf, wire, tag_base + group.len() as u64)
}

/// Position of `rank` in `group`, or None.
pub fn position_in(group: &[usize], rank: usize) -> Option<usize> {
    group.iter().position(|&r| r == rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::transport::Mesh;
    use std::thread;

    fn run_group<F>(n: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&mut dyn Transport, usize) -> Vec<f32> + Send + Sync + 'static,
    {
        let eps = Mesh::new(n);
        let f = std::sync::Arc::new(f);
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let f = f.clone();
                thread::spawn(move || f(&mut ep, rank))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn test_vector(rank: usize, n_elems: usize) -> Vec<f32> {
        (0..n_elems)
            .map(|i| ((rank + 1) * (i + 1)) as f32 * 0.001)
            .collect()
    }

    fn expected_sum(n_ranks: usize, n_elems: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; n_elems];
        for r in 0..n_ranks {
            for (a, v) in acc.iter_mut().zip(test_vector(r, n_elems)) {
                *a += v;
            }
        }
        acc
    }

    #[test]
    fn chunk_offsets_cover_exactly() {
        for (n, k) in [(10, 3), (7, 7), (3, 5), (0, 2), (100, 1)] {
            let offs = chunk_offsets(n, k);
            assert_eq!(offs.len(), k + 1);
            assert_eq!(offs[0], 0);
            assert_eq!(offs[k], n);
            for w in offs.windows(2) {
                assert!(w[0] <= w[1]);
                assert!(w[1] - w[0] <= n / k + 1);
            }
        }
    }

    #[test]
    fn ring_all_reduce_matches_sum() {
        for n in [1usize, 2, 3, 4, 7] {
            let elems = 103;
            let group: Vec<usize> = (0..n).collect();
            let results = run_group(n, move |ep, rank| {
                let group: Vec<usize> = (0..n).collect();
                let mut buf = test_vector(rank, elems);
                ring_all_reduce(ep, &group, rank, &mut buf, Wire::F32, 0).unwrap();
                buf
            });
            let want = expected_sum(n, elems);
            for (r, got) in results.iter().enumerate() {
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-4, "n={n} rank={r}: {g} vs {w}");
                }
            }
            drop(group);
        }
    }

    #[test]
    fn reduce_scatter_owned_chunk_is_correct() {
        let n = 4;
        let elems = 37; // uneven chunks
        let results = run_group(n, move |ep, rank| {
            let group: Vec<usize> = (0..n).collect();
            let mut buf = test_vector(rank, elems);
            let owned = ring_reduce_scatter(ep, &group, rank, &mut buf, Wire::F32, 0).unwrap();
            let offs = chunk_offsets(elems, n);
            let mut tagged = vec![owned as f32];
            tagged.extend_from_slice(&buf[offs[owned]..offs[owned + 1]]);
            tagged
        });
        let want = expected_sum(n, elems);
        let offs = chunk_offsets(elems, n);
        let mut seen = vec![false; n];
        for got in &results {
            let owned = got[0] as usize;
            seen[owned] = true;
            let want_chunk = &want[offs[owned]..offs[owned + 1]];
            for (g, w) in got[1..].iter().zip(want_chunk) {
                assert!((g - w).abs() < 1e-4);
            }
        }
        assert!(seen.iter().all(|&s| s), "every chunk owned exactly once");
    }

    #[test]
    fn all_reduce_on_sub_group_leaves_others_untouched() {
        // Ranks 1..3 of a 4-mesh reduce among themselves; rank 0 idles.
        let results = run_group(4, move |ep, rank| {
            let group = vec![1usize, 2, 3];
            let mut buf = test_vector(rank, 50);
            if let Some(pos) = position_in(&group, rank) {
                ring_all_reduce(ep, &group, pos, &mut buf, Wire::F32, 0).unwrap();
            }
            buf
        });
        // rank 0 unchanged
        assert_eq!(results[0], test_vector(0, 50));
        // ranks 1..3 hold sum of their three vectors
        let mut want = vec![0.0f32; 50];
        for r in 1..4 {
            for (a, v) in want.iter_mut().zip(test_vector(r, 50)) {
                *a += v;
            }
        }
        for r in 1..4 {
            for (g, w) in results[r].iter().zip(&want) {
                assert!((g - w).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fp16_wire_reduces_with_bounded_error() {
        let n = 4;
        let elems = 64;
        let results = run_group(n, move |ep, rank| {
            let group: Vec<usize> = (0..n).collect();
            let mut buf = test_vector(rank, elems);
            ring_all_reduce(ep, &group, rank, &mut buf, Wire::F16, 0).unwrap();
            buf
        });
        let want = expected_sum(n, elems);
        for got in &results {
            for (g, w) in got.iter().zip(&want) {
                // f16 has ~3 decimal digits; values here are O(0.001..0.5)
                let tol = (w.abs() * 4e-3).max(1e-4);
                assert!((g - w).abs() < tol, "{g} vs {w}");
            }
        }
        // all ranks agree exactly? Not guaranteed by fp16 path ordering, but
        // ranks received identical final chunks during all-gather:
        for r in 1..n {
            assert_eq!(results[0], results[r], "ranks must agree bit-for-bit");
        }
    }

    /// After one warm-up all-reduce the endpoint freelist feeds every
    /// subsequent hop: the second reduction allocates no new wire buffers
    /// (observable as freelist hits) and still sums correctly.
    #[test]
    fn back_to_back_reductions_reuse_wire_buffers() {
        for wire in [Wire::F32, Wire::F16] {
            let n = 4;
            let elems = 64;
            let results = run_group(n, move |ep, rank| {
                let group: Vec<usize> = (0..n).collect();
                let mut buf = test_vector(rank, elems);
                ring_all_reduce(ep, &group, rank, &mut buf, wire, 0).unwrap();
                let hits_after_warmup = ep.freelist_hits();
                let mut buf2 = test_vector(rank, elems);
                ring_all_reduce(ep, &group, rank, &mut buf2, wire, 100).unwrap();
                assert!(
                    ep.freelist_hits() > hits_after_warmup,
                    "second reduction must draw from the freelist"
                );
                buf2
            });
            let want = expected_sum(n, elems);
            for got in &results {
                for (g, w) in got.iter().zip(&want) {
                    let tol = (w.abs() * 4e-3).max(1e-3);
                    assert!((g - w).abs() < tol, "{wire:?}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn elems_fewer_than_ranks() {
        // Degenerate chunking: some chunks are empty.
        let n = 5;
        let results = run_group(n, move |ep, rank| {
            let group: Vec<usize> = (0..n).collect();
            let mut buf = test_vector(rank, 3);
            ring_all_reduce(ep, &group, rank, &mut buf, Wire::F32, 0).unwrap();
            buf
        });
        let want = expected_sum(n, 3);
        for got in &results {
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4);
            }
        }
    }
}
