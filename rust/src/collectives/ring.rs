//! Flat ring all-reduce — the paper's baseline (its ref. [14], Baidu).
//!
//! `2(N-1)` peer-to-peer steps over the full world. Bandwidth-optimal per
//! link, but the step count grows linearly with the number of GPUs, which is
//! exactly the latency wall the paper's 2D-torus removes at ABCI scale
//! (paper §2.2).

use anyhow::Result;

use super::primitives::{ring_all_reduce, Wire};
use super::transport::Transport;
use super::Collective;

/// Flat ring over all ranks in the mesh.
#[derive(Debug, Clone, Default)]
pub struct RingAllReduce;

impl Collective for RingAllReduce {
    fn name(&self) -> String {
        "ring".to_string()
    }

    fn all_reduce(
        &self,
        ep: &mut dyn Transport,
        buf: &mut [f32],
        wire: Wire,
        tag_base: u64,
    ) -> Result<()> {
        let n = ep.world_size();
        let group: Vec<usize> = (0..n).collect();
        let me = ep.rank();
        ring_all_reduce(ep, &group, me, buf, wire, tag_base)
    }

    fn p2p_steps(&self, n_ranks: usize) -> usize {
        2 * (n_ranks - 1)
    }

    fn tag_span(&self, n_ranks: usize) -> u64 {
        2 * n_ranks as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::test_support::{check_all_reduce_matches_sum, run_collective};

    #[test]
    fn matches_sequential_sum() {
        for n in [1usize, 2, 3, 5, 8] {
            check_all_reduce_matches_sum(&RingAllReduce, n, 101, Wire::F32, 1e-4);
        }
    }

    #[test]
    fn fp16_wire_bounded_error_and_agreement() {
        check_all_reduce_matches_sum(&RingAllReduce, 6, 64, Wire::F16, 5e-3);
    }

    #[test]
    fn step_count_formula() {
        assert_eq!(RingAllReduce.p2p_steps(1024), 2046);
        assert_eq!(RingAllReduce.p2p_steps(2), 2);
    }

    #[test]
    fn data_volume_matches_ring_formula() {
        // Each rank sends 2(N-1)/N * n elements.
        let n = 4usize;
        let elems = 100usize;
        let (results, counters) = run_collective(&RingAllReduce, n, elems, Wire::F32);
        drop(results);
        let (sent, recvd, msgs) = counters;
        assert_eq!(sent, recvd);
        assert_eq!(msgs, (n * 2 * (n - 1)) as u64);
        assert_eq!(sent, (n * 2 * (n - 1) / n * elems * 4) as u64);
    }
}
