//! Recursive halving-doubling all-reduce — the scheme Ying et al. [8] use
//! on TPU pods (paper Table 1's 1.8-minute comparator) and MPI's classic
//! large-message algorithm.
//!
//! `log2(N)` rounds of reduce-scatter with exponentially growing stride and
//! halving payload, then `log2(N)` rounds of all-gather in reverse:
//! `2·log2(N)` p2p steps total — fewer than both the flat ring and the
//! 2D-torus — at the cost of long-haul pairings (stride N/2 hops cross the
//! whole fabric, which is why torus wins on torus-shaped networks and
//! halving-doubling wins on full-bisection pods).
//!
//! Requires a power-of-two world size (the classic algorithm; non-2^k
//! variants exist but the paper's comparators all run 2^k).

use anyhow::{bail, Result};

use super::primitives::Wire;
use super::transport::{frame, Payload, Transport};
use super::Collective;

/// Recursive halving-doubling all-reduce over the full mesh.
#[derive(Debug, Clone, Copy, Default)]
pub struct HalvingDoubling;

fn send_range(
    ep: &mut dyn Transport,
    dst: usize,
    tag: u64,
    chunk: &[f32],
    wire: Wire,
) -> Result<()> {
    match wire {
        Wire::F32 => ep.send_f32(dst, tag, chunk),
        Wire::F16 => {
            let mut enc = ep.alloc_f16(chunk.len());
            frame::encode_f16(chunk, &mut enc);
            ep.send_f16(dst, tag, enc)
        }
    }
}

/// Receive one window as f32. The returned buffer comes from / goes back
/// to the endpoint freelist (callers recycle it after consuming).
fn recv_range(ep: &mut dyn Transport, src: usize, tag: u64, wire: Wire) -> Result<Vec<f32>> {
    match ep.recv(src, tag)? {
        Payload::F32(v) if wire == Wire::F32 => Ok(v),
        Payload::F16(v) if wire == Wire::F16 => {
            let mut out = ep.alloc_f32(v.len());
            frame::decode_f16(&v, &mut out);
            ep.recycle_f16(v);
            Ok(out)
        }
        _ => bail!("wire dtype mismatch"),
    }
}

/// Window of `rank` after `rounds_applied` halving rounds over `[0, len)`.
///
/// Round s splits the parent window at its midpoint; the rank whose bit s
/// is 0 keeps the low half. With odd window sizes the halves differ by one
/// element, so partner windows are NOT generally equal-width — both phases
/// below derive each side's exact window from this recursion instead of
/// assuming symmetry.
fn window(rank: usize, rounds_applied: usize, len: usize) -> (usize, usize) {
    let (mut lo, mut hi) = (0usize, len);
    for s in 0..rounds_applied {
        let mid = lo + (hi - lo) / 2;
        if rank & (1 << s) == 0 {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    (lo, hi)
}

impl Collective for HalvingDoubling {
    fn name(&self) -> String {
        "halving-doubling".to_string()
    }

    fn all_reduce(
        &self,
        ep: &mut dyn Transport,
        buf: &mut [f32],
        wire: Wire,
        tag_base: u64,
    ) -> Result<()> {
        let n = ep.world_size();
        if !n.is_power_of_two() {
            bail!("halving-doubling needs a power-of-two world, got {n}");
        }
        if n == 1 {
            return Ok(());
        }
        let me = ep.rank();
        let rounds = n.trailing_zeros() as usize;
        let len = buf.len();

        // Reduce-scatter: at round r (stride 2^r) send the partner's child
        // window of the shared parent, accumulate into mine.
        for r in 0..rounds {
            let partner = me ^ (1 << r);
            let (mine_lo, mine_hi) = window(me, r + 1, len);
            let (theirs_lo, theirs_hi) = window(partner, r + 1, len);
            let tag = tag_base + r as u64;
            send_range(ep, partner, tag, &buf[theirs_lo..theirs_hi], wire)?;
            match wire {
                Wire::F32 => {
                    let incoming = match ep.recv(partner, tag)? {
                        Payload::F32(v) => v,
                        Payload::F16(_) => bail!("wire dtype mismatch"),
                    };
                    let dst = &mut buf[mine_lo..mine_hi];
                    debug_assert_eq!(dst.len(), incoming.len());
                    for (d, s) in dst.iter_mut().zip(&incoming) {
                        *d += s;
                    }
                    ep.recycle_f32(incoming);
                }
                Wire::F16 => {
                    let enc = match ep.recv(partner, tag)? {
                        Payload::F16(v) => v,
                        Payload::F32(_) => bail!("wire dtype mismatch"),
                    };
                    // fused decode+add+requantise (fp16 buffer semantics)
                    frame::accumulate_f16(&mut buf[mine_lo..mine_hi], &enc);
                    ep.recycle_f16(enc);
                }
            }
        }

        // All-gather: reverse the recursion; each side contributes its own
        // child window of the shared parent, widths taken from the
        // recursion (they may differ by one element).
        for r in (0..rounds).rev() {
            let partner = me ^ (1 << r);
            let (mine_lo, mine_hi) = window(me, r + 1, len);
            let (theirs_lo, theirs_hi) = window(partner, r + 1, len);
            let tag = tag_base + (rounds + r) as u64;
            send_range(ep, partner, tag, &buf[mine_lo..mine_hi], wire)?;
            let incoming = recv_range(ep, partner, tag, wire)?;
            if incoming.len() != theirs_hi - theirs_lo {
                bail!(
                    "halving-doubling gather: expected {} elems from rank {partner}, got {}",
                    theirs_hi - theirs_lo,
                    incoming.len()
                );
            }
            buf[theirs_lo..theirs_hi].copy_from_slice(&incoming);
            ep.recycle_f32(incoming);
        }
        Ok(())
    }

    fn p2p_steps(&self, n_ranks: usize) -> usize {
        2 * n_ranks.trailing_zeros() as usize
    }

    fn tag_span(&self, n_ranks: usize) -> u64 {
        2 * n_ranks.trailing_zeros() as u64 + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::test_support::check_all_reduce_matches_sum;

    #[test]
    fn matches_sequential_sum_powers_of_two() {
        for n in [1usize, 2, 4, 8, 16] {
            check_all_reduce_matches_sum(&HalvingDoubling, n, 96, Wire::F32, 1e-4);
        }
    }

    #[test]
    fn uneven_lengths_work() {
        // windows with odd splits: 97 does not divide by 8 evenly
        check_all_reduce_matches_sum(&HalvingDoubling, 8, 97, Wire::F32, 1e-4);
        check_all_reduce_matches_sum(&HalvingDoubling, 4, 1, Wire::F32, 1e-4);
        check_all_reduce_matches_sum(&HalvingDoubling, 4, 3, Wire::F32, 1e-4);
    }

    #[test]
    fn fp16_wire_agreement() {
        check_all_reduce_matches_sum(&HalvingDoubling, 8, 64, Wire::F16, 5e-3);
    }

    #[test]
    fn rejects_non_power_of_two() {
        let mut eps = crate::collectives::transport::Mesh::new(3);
        let mut ep = eps.remove(0);
        let mut buf = vec![0.0f32; 8];
        assert!(HalvingDoubling.all_reduce(&mut ep, &mut buf, Wire::F32, 0).is_err());
    }

    #[test]
    fn step_count_is_logarithmic() {
        assert_eq!(HalvingDoubling.p2p_steps(1024), 20);
        assert_eq!(HalvingDoubling.p2p_steps(4096), 24);
        // far fewer steps than ring (2046) or torus 32x32 (124) at 1024
        assert!(HalvingDoubling.p2p_steps(1024) < 124);
    }
}
