//! Gradient-synchronisation collectives — the paper's communication layer.
//!
//! This module sits where NCCL sits in the paper's stack (§3.1): the
//! coordinator hands each worker thread a [`transport::Transport`]
//! endpoint (the in-memory [`transport::Endpoint`] by default, a
//! socket-backed [`transport::TcpEndpoint`] under `[transport] mode =
//! "tcp"`) and a shared [`Collective`]; after every `grad_step` the
//! workers call [`Collective::all_reduce`] on their flattened gradient
//! buffer (FP16 on the wire) and on their BN statistics (FP32), then
//! divide by the world size and run `apply_step`. The schedules only ever
//! see the trait, so every algorithm below runs unchanged over either
//! channel.
//!
//! Three algorithms are provided, matching the paper's comparison set:
//!
//! | impl | scheme | per-rank p2p steps |
//! |---|---|---|
//! | [`ring::RingAllReduce`] | flat ring (Baidu [14]) | `2(N-1)` |
//! | [`hierarchical::HierarchicalAllReduce`] | grouped rings (Jia [6]) | `2(g-1) + 2(N/g-1)` |
//! | [`torus2d::TorusAllReduce`] | **2D-Torus (this paper)** | `2(X-1) + 2(Y-1)` |
//!
//! On top of any of them, [`bucketed`] splits the gradient into
//! tensor-aligned buckets (reverse parameter order — the order backprop
//! finalises gradients) and reduces each bucket in its own disjoint
//! `tag_span` window, which is what lets the worker overlap the all-reduce
//! with the backward pass (paper §2.2's comm/compute overlap).

pub mod bucketed;
pub mod halving_doubling;
pub mod hierarchical;
pub mod primitives;
pub mod ring;
pub mod torus2d;
pub mod transport;

pub use bucketed::{BucketPlan, BucketStaging};
pub use halving_doubling::HalvingDoubling;
pub use hierarchical::HierarchicalAllReduce;
pub use primitives::Wire;
pub use ring::RingAllReduce;
pub use torus2d::TorusAllReduce;
pub use transport::{
    presumed_wedged, BackoffConfig, ChaosConfig, ChaosCounters, ChaosTransport, Counters,
    Endpoint, Health, LinkPolicy, Mesh, MeshError, Payload, TcpEndpoint, TcpMesh, TcpOptions,
    Transport,
};

use anyhow::Result;

/// A sum-all-reduce collective over the whole mesh.
///
/// Every rank's worker thread calls `all_reduce` with its own endpoint and
/// its local buffer; on return every rank holds the element-wise sum across
/// ranks (callers divide by N to average). `tag_base` must leave
/// [`Collective::tag_span`] tags of room before the next concurrent
/// collective on the same endpoints.
pub trait Collective: Send + Sync {
    /// Human-readable name (used in metrics and bench tables).
    fn name(&self) -> String;

    /// In-place sum across all ranks. Collective: every rank must call it.
    fn all_reduce(
        &self,
        ep: &mut dyn Transport,
        buf: &mut [f32],
        wire: Wire,
        tag_base: u64,
    ) -> Result<()>;

    /// Analytic per-rank peer-to-peer step count (cross-checked by simnet).
    fn p2p_steps(&self, n_ranks: usize) -> usize;

    /// Width of the tag window this collective may use from `tag_base`.
    fn tag_span(&self, n_ranks: usize) -> u64;
}

/// Construct a collective by name: `ring`, `hierarchical:<g>`, `torus:<X>x<Y>`.
pub fn by_name(spec: &str, n_ranks: usize) -> Result<Box<dyn Collective>> {
    use anyhow::{anyhow, bail};
    if spec == "ring" {
        return Ok(Box::new(RingAllReduce));
    }
    if spec == "halving-doubling" {
        if !n_ranks.is_power_of_two() {
            bail!("halving-doubling needs a power-of-two world, got {n_ranks}");
        }
        return Ok(Box::new(HalvingDoubling));
    }
    if let Some(g) = spec.strip_prefix("hierarchical:") {
        let g: usize = g.parse().map_err(|_| anyhow!("bad group size in {spec:?}"))?;
        return Ok(Box::new(HierarchicalAllReduce::new(g)));
    }
    if let Some(dims) = spec.strip_prefix("torus:") {
        let (x, y) = dims
            .split_once('x')
            .ok_or_else(|| anyhow!("torus spec must be torus:<X>x<Y>, got {spec:?}"))?;
        let x: usize = x.parse().map_err(|_| anyhow!("bad X in {spec:?}"))?;
        let y: usize = y.parse().map_err(|_| anyhow!("bad Y in {spec:?}"))?;
        if x * y != n_ranks {
            bail!("torus {x}x{y} does not cover {n_ranks} ranks");
        }
        return Ok(Box::new(TorusAllReduce::new(x, y)));
    }
    if spec == "torus" {
        // Auto-shape: most-square grid for n_ranks. A degenerate y == 1
        // grid (prime n, or n == 1) is a flat ring wearing torus tag and
        // phase overhead — route it to the real ring instead. Recovery's
        // re-planning goes through this same path, so an awkward survivor
        // count gets the same treatment.
        let (x, y) = crate::cluster::grid::best_grid(n_ranks);
        if y == 1 {
            debug_assert_eq!(x, n_ranks);
            return Ok(Box::new(RingAllReduce));
        }
        return Ok(Box::new(TorusAllReduce::new(x, y)));
    }
    anyhow::bail!("unknown collective {spec:?} (ring | hierarchical:<g> | torus[:<X>x<Y>])")
}

/// Resolve `spec` for a possibly *degraded* world (mid-run recovery after
/// rank deaths). A fixed-shape spec that no longer fits the survivor count
/// — `torus:<X>x<Y>` with `X·Y ≠ n`, `halving-doubling` on a non-power-of-
/// two world, `hierarchical:<g>` with `g ∤ n` — falls back to the
/// auto-shaped `"torus"` rule (most-square grid, ring when degenerate)
/// instead of failing the whole run. With `degraded = false` this is
/// exactly [`by_name`].
pub fn by_name_elastic(spec: &str, n_ranks: usize, degraded: bool) -> Result<Box<dyn Collective>> {
    // `hierarchical:<g>` only validates g | n inside all_reduce; check it
    // here so a degraded world falls back instead of failing mid-phase.
    let hier_misfit = spec
        .strip_prefix("hierarchical:")
        .and_then(|g| g.parse::<usize>().ok())
        .is_some_and(|g| g == 0 || n_ranks % g != 0);
    let built = if hier_misfit {
        Err(anyhow::anyhow!(
            "hierarchical spec {spec:?} does not divide {n_ranks} ranks"
        ))
    } else {
        by_name(spec, n_ranks)
    };
    match built {
        Ok(c) => Ok(c),
        Err(e) if degraded => {
            by_name("torus", n_ranks).map_err(|_| e) // torus auto never fails
        }
        Err(e) => Err(e),
    }
}

/// Shared helpers for collective tests (compiled into unit + integration
/// tests; kept here so every algorithm checks the identical invariants).
#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use std::thread;

    /// Deterministic per-rank test vector.
    pub fn test_vector(rank: usize, n_elems: usize) -> Vec<f32> {
        (0..n_elems)
            .map(|i| ((rank + 1) as f32 * 0.37 + i as f32 * 0.011).sin() * 0.5)
            .collect()
    }

    pub fn expected_sum(n_ranks: usize, n_elems: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; n_elems];
        for r in 0..n_ranks {
            for (a, v) in acc.iter_mut().zip(test_vector(r, n_elems)) {
                *a += v;
            }
        }
        acc
    }

    /// Run `coll` across `n` ranks; return per-rank results and (sent,
    /// received, messages) counters.
    pub fn run_collective<C: Collective + Clone + 'static>(
        coll: &C,
        n: usize,
        elems: usize,
        wire: Wire,
    ) -> (Vec<Vec<f32>>, (u64, u64, u64)) {
        let eps = Mesh::new(n);
        let counters = eps[0].counters_arc();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let coll = coll.clone();
                thread::spawn(move || {
                    let mut buf = test_vector(ep.rank(), elems);
                    coll.all_reduce(&mut ep, &mut buf, wire, 0).unwrap();
                    buf
                })
            })
            .collect();
        let mut results = Vec::new();
        for h in handles {
            results.push(h.join().unwrap());
        }
        // snapshot only after every rank thread has fully finished
        (results, counters.snapshot())
    }

    /// The core invariant: all-reduce ≡ sequential sum, on every rank, and
    /// all ranks agree bit-for-bit.
    pub fn check_all_reduce_matches_sum<C: Collective + Clone + 'static>(
        coll: &C,
        n: usize,
        elems: usize,
        wire: Wire,
        tol: f32,
    ) {
        let (results, (sent, recvd, _)) = run_collective(coll, n, elems, wire);
        assert_eq!(sent, recvd, "byte conservation");
        let want = expected_sum(n, elems);
        for (rank, got) in results.iter().enumerate() {
            assert_eq!(got.len(), elems);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= tol + w.abs() * tol,
                    "{}: rank {rank} elem {i}: got {g}, want {w}",
                    coll.name()
                );
            }
        }
        for r in 1..n {
            assert_eq!(results[0], results[r], "ranks 0 and {r} must agree");
        }
    }
}
