//! Learning-rate and momentum schedules (paper §3.2, Table 3).
//!
//! Two configurations:
//!
//! **Config A** — from the TensorFlow TPU ResNet/LARS recipe: linear warmup
//! over 34 epochs from `initial` (1e-5) to `base` (34.0), then polynomial
//! (power-2) decay to zero at `total_epochs`; momentum fixed at 0.9.
//!
//! **Config B** — the paper's own formula (from [10]'s settings):
//!
//! ```text
//! lr(e) = 0.2 + (29 - 0.2)·e/5          e < 5      (warmup)
//!       = 29·(1 - e/90)²                e < 30
//!       = 50·(1 - e/90)²                otherwise
//! ```
//!
//! plus a momentum chosen per Smith & Le's noise-scale relation [16] so the
//! SGD noise scale stays at the 32K-batch reference as the batch grows:
//! `noise ∝ lr·N/(B(1-m))`; holding it equal to the reference
//! `(B_ref = 32·1024, m_ref = 0.9)` gives
//!
//! ```text
//! momentum(B) = 1 - B_ref·(1 - m_ref)/B
//! ```
//!
//! (the paper prints this relation in a typeset-garbled form; the inverse
//! reduces to exactly `m(32K) = 0.9`, which pins the constant).

/// Reference batch and momentum anchoring config B's noise scale.
pub const NOISE_REF_BATCH: f64 = 32.0 * 1024.0;
pub const NOISE_REF_MOMENTUM: f64 = 0.9;

/// A learning-rate schedule over epochs (continuous epoch argument).
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Constant LR (debug / tiny runs).
    Const { lr: f64, momentum: f64 },
    /// Config A (paper Table 3): long linear warmup + poly-2 decay.
    ConfigA {
        base: f64,
        initial: f64,
        warmup_epochs: f64,
        total_epochs: f64,
    },
    /// Config B (paper Table 3): the formula block above.
    ConfigB {
        warmup_epochs: f64,
        warmup_start: f64,
        base_low: f64,
        base_high: f64,
        switch_epoch: f64,
        total_epochs: f64,
    },
}

impl LrSchedule {
    /// Paper defaults for config A.
    pub fn config_a() -> Self {
        LrSchedule::ConfigA {
            base: 34.0,
            initial: 1e-5,
            warmup_epochs: 34.0,
            total_epochs: 90.0,
        }
    }

    /// Paper defaults for config B.
    pub fn config_b() -> Self {
        LrSchedule::ConfigB {
            warmup_epochs: 5.0,
            warmup_start: 0.2,
            base_low: 29.0,
            base_high: 50.0,
            switch_epoch: 30.0,
            total_epochs: 90.0,
        }
    }

    /// Learning rate at (fractional) `epoch`.
    pub fn lr(&self, epoch: f64) -> f64 {
        match *self {
            LrSchedule::Const { lr, .. } => lr,
            LrSchedule::ConfigA {
                base,
                initial,
                warmup_epochs,
                total_epochs,
            } => {
                if epoch < warmup_epochs {
                    initial + (base - initial) * epoch / warmup_epochs
                } else {
                    let t = ((epoch - warmup_epochs) / (total_epochs - warmup_epochs)).min(1.0);
                    base * (1.0 - t) * (1.0 - t)
                }
            }
            LrSchedule::ConfigB {
                warmup_epochs,
                warmup_start,
                base_low,
                base_high,
                switch_epoch,
                total_epochs,
            } => {
                if epoch < warmup_epochs {
                    warmup_start + (base_low - warmup_start) * epoch / warmup_epochs
                } else {
                    let base = if epoch < switch_epoch { base_low } else { base_high };
                    let f = 1.0 - (epoch / total_epochs).min(1.0);
                    base * f * f
                }
            }
        }
    }

    /// Momentum at `epoch` for global batch `total_batch`.
    pub fn momentum(&self, _epoch: f64, total_batch: usize) -> f64 {
        match *self {
            LrSchedule::Const { momentum, .. } => momentum,
            // Config A runs plain 0.9 (paper §3.2).
            LrSchedule::ConfigA { .. } => 0.9,
            // Config B: noise-scale-matched momentum (module docs).
            LrSchedule::ConfigB { .. } => {
                let m = 1.0 - NOISE_REF_BATCH * (1.0 - NOISE_REF_MOMENTUM) / total_batch as f64;
                m.clamp(0.0, 0.999)
            }
        }
    }

    /// Linear-scaling transfer of a paper-scale base LR to a reduced-scale
    /// twin: LARS base LRs scale ~linearly with global batch (Goyal [1]).
    pub fn scale_lr(paper_lr: f64, paper_batch: usize, actual_batch: usize) -> f64 {
        paper_lr * actual_batch as f64 / paper_batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_a_warmup_endpoints() {
        let s = LrSchedule::config_a();
        assert!((s.lr(0.0) - 1e-5).abs() < 1e-12);
        // end of warmup hits base
        assert!((s.lr(34.0) - 34.0).abs() < 1e-9);
        // midway through warmup ~ half of base
        assert!((s.lr(17.0) - 17.0).abs() < 0.01);
        // decays to 0 at epoch 90
        assert!(s.lr(90.0).abs() < 1e-9);
        assert_eq!(s.momentum(10.0, 65536), 0.9);
    }

    #[test]
    fn config_b_matches_paper_formula() {
        let s = LrSchedule::config_b();
        // warmup: 0.2 -> 29 over 5 epochs
        assert!((s.lr(0.0) - 0.2).abs() < 1e-12);
        assert!((s.lr(5.0) - 29.0 * (1.0f64 - 5.0 / 90.0).powi(2)).abs() < 0.45);
        // epoch 10: 29(1-10/90)^2
        assert!((s.lr(10.0) - 29.0 * (8.0 / 9.0_f64).powi(2)).abs() < 1e-9);
        // epoch 40: 50(1-40/90)^2
        assert!((s.lr(40.0) - 50.0 * (5.0 / 9.0_f64).powi(2)).abs() < 1e-9);
        // switch at 30 jumps base 29 -> 50
        assert!(s.lr(30.0) > s.lr(29.999));
    }

    #[test]
    fn config_b_momentum_anchored_at_reference() {
        let s = LrSchedule::config_b();
        // at the 32K reference batch the relation must give exactly 0.9
        assert!((s.momentum(0.0, 32 * 1024) - 0.9).abs() < 1e-12);
        // larger batches -> larger momentum (paper's point)
        let m54k = s.momentum(0.0, 54 * 1024);
        assert!(m54k > 0.9 && m54k < 1.0);
        assert!((m54k - (1.0 - 3276.8 / 55296.0)).abs() < 1e-3);
        // small batches clamp at 0 rather than going negative
        assert_eq!(s.momentum(0.0, 128), 0.0);
    }

    #[test]
    fn lr_is_continuous_within_phases() {
        let s = LrSchedule::config_b();
        for e in [1.0, 4.9, 6.0, 29.0, 31.0, 89.0] {
            let d = (s.lr(e + 1e-6) - s.lr(e)).abs();
            assert!(d < 1e-3, "jump at {e}");
        }
    }

    #[test]
    fn scale_lr_linear() {
        assert_eq!(LrSchedule::scale_lr(29.0, 32768, 32768), 29.0);
        assert!((LrSchedule::scale_lr(29.0, 32768, 256) - 0.2265625).abs() < 1e-9);
    }

    #[test]
    fn const_schedule() {
        let s = LrSchedule::Const { lr: 0.5, momentum: 0.85 };
        assert_eq!(s.lr(3.0), 0.5);
        assert_eq!(s.momentum(3.0, 1024), 0.85);
    }
}
