//! Batch-size control (paper §2.1, Table 3): a predetermined schedule of
//! (per-worker batch, worker count) phases over epochs.
//!
//! Increasing the global batch as the loss landscape flattens lets training
//! evade the early instability of huge batches ([4], [11], [12]); the paper
//! drives it by switching per-worker batch 16→32 (and, in Exp. 4, growing
//! the worker pool). In this system a phase switch makes the coordinator
//! swap every worker's `grad_step` executable for the new batch size — the
//! optimizer state and parameters carry over untouched.

/// One phase of the batch-size schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Phase {
    /// First epoch (inclusive) at which this phase is active.
    pub from_epoch: u32,
    /// Per-worker mini-batch.
    pub per_worker: usize,
    /// Number of data-parallel workers in this phase.
    pub workers: usize,
}

impl Phase {
    pub fn total_batch(&self) -> usize {
        self.per_worker * self.workers
    }
}

/// A batch-size-control schedule: ordered phases + total epochs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchSchedule {
    phases: Vec<Phase>,
    pub total_epochs: u32,
}

impl BatchSchedule {
    /// Build from phases; they must start at epoch 0 and be strictly
    /// increasing in `from_epoch`.
    pub fn new(phases: Vec<Phase>, total_epochs: u32) -> Self {
        assert!(!phases.is_empty(), "need at least one phase");
        assert_eq!(phases[0].from_epoch, 0, "first phase must start at epoch 0");
        for w in phases.windows(2) {
            assert!(
                w[0].from_epoch < w[1].from_epoch,
                "phases must be strictly increasing"
            );
        }
        assert!(phases.iter().all(|p| p.per_worker > 0 && p.workers > 0));
        Self {
            phases,
            total_epochs,
        }
    }

    /// Constant-batch schedule (the paper's Reference row).
    pub fn constant(per_worker: usize, workers: usize, total_epochs: u32) -> Self {
        Self::new(
            vec![Phase {
                from_epoch: 0,
                per_worker,
                workers,
            }],
            total_epochs,
        )
    }

    /// Active phase at `epoch`.
    pub fn at(&self, epoch: u32) -> Phase {
        let mut cur = self.phases[0];
        for &p in &self.phases {
            if p.from_epoch <= epoch {
                cur = p;
            } else {
                break;
            }
        }
        cur
    }

    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    /// Maximum worker count over the run (the paper's "#GPUs (Max)").
    pub fn max_workers(&self) -> usize {
        self.phases.iter().map(|p| p.workers).max().unwrap()
    }

    pub fn min_total_batch(&self) -> usize {
        self.phases.iter().map(|p| p.total_batch()).min().unwrap()
    }

    pub fn max_total_batch(&self) -> usize {
        self.phases.iter().map(|p| p.total_batch()).max().unwrap()
    }

    /// Steps per epoch at `epoch` over a dataset of `dataset_size` samples
    /// (ceil division: the trailing partial batch still costs a step).
    pub fn steps_in_epoch(&self, epoch: u32, dataset_size: usize) -> usize {
        dataset_size.div_ceil(self.at(epoch).total_batch())
    }

    /// Total optimizer steps over the whole run.
    pub fn total_steps(&self, dataset_size: usize) -> usize {
        (0..self.total_epochs)
            .map(|e| self.steps_in_epoch(e, dataset_size))
            .sum()
    }

    /// Reduced-scale twin: same phase boundaries and per-worker batches,
    /// with worker counts scaled down to a test mesh of `target_workers`
    /// at the maximum phase (smaller phases scale proportionally, min 1).
    pub fn scaled_to(&self, target_workers: usize) -> BatchSchedule {
        let max_w = self.max_workers() as f64;
        let phases = self
            .phases
            .iter()
            .map(|p| Phase {
                from_epoch: p.from_epoch,
                per_worker: p.per_worker,
                workers: ((p.workers as f64 / max_w * target_workers as f64).round() as usize)
                    .max(1),
            })
            .collect();
        BatchSchedule::new(phases, self.total_epochs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp4_like() -> BatchSchedule {
        BatchSchedule::new(
            vec![
                Phase { from_epoch: 0, per_worker: 16, workers: 2176 },
                Phase { from_epoch: 30, per_worker: 16, workers: 4096 },
                Phase { from_epoch: 45, per_worker: 32, workers: 2656 },
                Phase { from_epoch: 75, per_worker: 32, workers: 3712 },
            ],
            90,
        )
    }

    #[test]
    fn lookup_respects_boundaries() {
        let s = exp4_like();
        assert_eq!(s.at(0).total_batch(), 34816);
        assert_eq!(s.at(29).total_batch(), 34816);
        assert_eq!(s.at(30).total_batch(), 65536);
        assert_eq!(s.at(45).per_worker, 32);
        assert_eq!(s.at(89).workers, 3712);
        // beyond the last boundary stays in the last phase
        assert_eq!(s.at(500).workers, 3712);
    }

    #[test]
    fn table3_exp4_batch_extremes() {
        // Paper Table 5 row Exp. 4: batch 34K min, 119K max.
        let s = exp4_like();
        assert_eq!(s.min_total_batch(), 34816); // "34K"
        assert_eq!(s.max_total_batch(), 118784); // "119K"
        assert_eq!(s.max_workers(), 4096);
    }

    #[test]
    fn steps_accounting() {
        let s = BatchSchedule::new(
            vec![
                Phase { from_epoch: 0, per_worker: 16, workers: 4 },
                Phase { from_epoch: 2, per_worker: 32, workers: 4 },
            ],
            4,
        );
        // dataset 1000: epochs 0,1 at 64/step -> 16 steps; 2,3 at 128 -> 8
        assert_eq!(s.steps_in_epoch(0, 1000), 16);
        assert_eq!(s.steps_in_epoch(3, 1000), 8);
        assert_eq!(s.total_steps(1000), 16 + 16 + 8 + 8);
    }

    #[test]
    fn scaled_twin_preserves_structure() {
        let s = exp4_like().scaled_to(8);
        assert_eq!(s.max_workers(), 8);
        assert_eq!(s.phases().len(), 4);
        // per-worker batches unchanged; boundaries unchanged
        assert_eq!(s.at(0).per_worker, 16);
        assert_eq!(s.at(45).per_worker, 32);
        assert_eq!(s.at(0).workers, 4); // 2176/4096*8 ≈ 4.25 -> 4
        assert_eq!(s.phases()[0].from_epoch, 0);
    }

    #[test]
    #[should_panic]
    fn rejects_unordered_phases() {
        BatchSchedule::new(
            vec![
                Phase { from_epoch: 0, per_worker: 16, workers: 4 },
                Phase { from_epoch: 0, per_worker: 32, workers: 4 },
            ],
            10,
        );
    }

    #[test]
    #[should_panic]
    fn rejects_missing_epoch_zero() {
        BatchSchedule::new(
            vec![Phase { from_epoch: 5, per_worker: 16, workers: 4 }],
            10,
        );
    }
}
