//! Training schedules: learning rate + momentum (paper configs A/B) and
//! batch-size control (the paper's first large-mini-batch stabiliser).

pub mod batchsize;
pub mod lr;

pub use batchsize::{BatchSchedule, Phase};
pub use lr::LrSchedule;
