//! Software IEEE-754 binary16 ("half") conversion.
//!
//! The paper exchanges gradients in FP16 on the wire while keeping LARS and
//! BN-statistic arithmetic in FP32 (§3.2). This module is the wire format:
//! `collectives::fp16` encodes each chunk with [`f32_to_f16`] before it
//! crosses a transport link and widens with [`f16_to_f32`] before reduction,
//! so the accuracy effects of half-precision exchange are faithfully
//! reproduced (round-to-nearest-even, Inf/NaN, subnormals).

/// Convert an `f32` to IEEE binary16 bits (round-to-nearest-even).
///
/// Branchless-ish fast path (Giesen's `float_to_half_fast3_rtne`): the
/// normal range rounds via integer bias arithmetic, subnormals via one FP
/// add against a magic constant (correct RTNE as long as the FPU rounds to
/// nearest even). Verified exhaustively against [`f32_to_f16_reference`]
/// for every f16 bit pattern and against RNE tie cases in tests.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    const F32_INFTY: u32 = 255 << 23;
    const F16_MAX: u32 = (127 + 16) << 23;
    const DENORM_MAGIC_BITS: u32 = ((127 - 15) + (23 - 10) + 1) << 23;
    let denorm_magic = f32::from_bits(DENORM_MAGIC_BITS);

    let bits = x.to_bits();
    let sign = (bits >> 16) as u16 & 0x8000;
    let mut f = bits & 0x7FFF_FFFF;

    let o: u16 = if f >= F16_MAX {
        // Inf or NaN (keep a NaN payload bit)
        if f > F32_INFTY {
            0x7E00
        } else {
            0x7C00
        }
    } else if f < (113 << 23) {
        // subnormal f16 (or zero): align the 10 mantissa bits at the
        // bottom of the float via one RNE addition
        let v = f32::from_bits(f) + denorm_magic;
        (v.to_bits().wrapping_sub(DENORM_MAGIC_BITS)) as u16
    } else {
        let mant_odd = (f >> 13) & 1;
        // exponent rebias + rounding bias, then tie-to-even nudge
        f = f.wrapping_add(0xC800_0FFF); // ((15-127)<<23) + 0xFFF
        f = f.wrapping_add(mant_odd);
        (f >> 13) as u16
    };
    sign | o
}

/// Scalar reference implementation (kept as the test oracle for the fast
/// path above; bit-identical by exhaustive test).
#[inline]
pub fn f32_to_f16_reference(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN (preserve a NaN payload bit).
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent.
    let e = exp - 127;
    if e > 15 {
        // Overflow -> Inf.
        return sign | 0x7C00;
    }
    if e >= -14 {
        // Normal range. 10-bit mantissa with round-to-nearest-even.
        let mant16 = mant >> 13;
        let round_bit = (mant >> 12) & 1;
        let sticky = (mant & 0x0FFF) != 0;
        let mut h = sign as u32 | (((e + 15) as u32) << 10) | mant16;
        if round_bit == 1 && (sticky || (mant16 & 1) == 1) {
            h += 1; // may carry into exponent; that is correct rounding
        }
        return h as u16;
    }
    if e >= -25 {
        // Subnormal half. e == -25 can still round UP to the smallest
        // subnormal (values above 2^-25 are nearer 2^-24 than 0).
        let shift = (-14 - e) as u32; // 0..=11
        let full = 0x0080_0000 | mant; // implicit leading 1
        let total_shift = 13 + shift;
        let mant16 = full >> total_shift;
        let round_bit = (full >> (total_shift - 1)) & 1;
        let sticky = (full & ((1 << (total_shift - 1)) - 1)) != 0;
        let mut h = sign as u32 | mant16;
        if round_bit == 1 && (sticky || (mant16 & 1) == 1) {
            h += 1;
        }
        return h as u16;
    }
    // Underflow -> signed zero.
    sign
}

/// Convert IEEE binary16 bits to `f32` (exact) via a 64K-entry lookup
/// table (256 KiB, built once) — ~1 load per element on the decode path of
/// every FP16 collective hop.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    decode_table()[h as usize]
}

fn decode_table() -> &'static [f32; 65536] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Box<[f32; 65536]>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = vec![0.0f32; 65536];
        for (h, slot) in t.iter_mut().enumerate() {
            *slot = f16_to_f32_reference(h as u16);
        }
        t.into_boxed_slice().try_into().unwrap()
    })
}

/// Scalar reference decode (test oracle + table builder).
#[inline]
pub fn f16_to_f32_reference(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        // Inf / NaN
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // Subnormal: value = (mant/1024)·2^-14. Normalise: with s
            // left-shifts to set bit 10, unbiased exp = -14 - s and the
            // f32 biased exponent is 113 - s.
            let mut s = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                s += 1;
            }
            m &= 0x03FF;
            sign | (((113 - s) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through f16 (the wire quantisation applied per value).
#[inline]
pub fn quantize_f16(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

/// Encode a slice in place-free fashion: `dst[i] = f16(src[i])`.
pub fn encode_slice(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f32_to_f16(s);
    }
}

/// Decode a slice: `dst[i] = f32(src[i])`.
pub fn decode_slice(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    let table = decode_table();
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = table[s as usize];
    }
}

/// Fused decode + accumulate + requantise: `dst[i] = f16(dst[i] + f32(src[i]))`
/// — the inner loop of an FP16 reduce-scatter hop (the buffer itself lives
/// in fp16, so the accumulated partial is requantised; one pass instead of
/// decode/add/quantise as three).
pub fn accumulate_quantized(dst: &mut [f32], src: &[u16]) {
    assert_eq!(src.len(), dst.len());
    let table = decode_table();
    for (d, &s) in dst.iter_mut().zip(src) {
        let sum = *d + table[s as usize];
        *d = table[f32_to_f16(sum) as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_encode_matches_reference_exhaustively_on_f16_grid() {
        // every finite f16 value, its neighbours, and RNE tie points
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue;
            }
            let f = f16_to_f32_reference(h);
            assert_eq!(f32_to_f16(f), f32_to_f16_reference(f), "pattern {h:#06x}");
        }
    }

    #[test]
    fn fast_encode_matches_reference_on_random_floats() {
        let mut rng = crate::util::rng::Pcg32::new(99);
        for _ in 0..200_000 {
            let bits = rng.next_u32();
            let x = f32::from_bits(bits);
            if x.is_nan() {
                // NaNs map to a canonical quiet NaN in both
                assert_eq!(f32_to_f16(x), f32_to_f16_reference(x), "bits {bits:#x}");
                continue;
            }
            assert_eq!(f32_to_f16(x), f32_to_f16_reference(x), "bits {bits:#x}");
        }
    }

    #[test]
    fn lut_decode_matches_reference() {
        for h in 0u16..=0xFFFF {
            let a = f16_to_f32(h);
            let b = f16_to_f32_reference(h);
            assert!(a == b || (a.is_nan() && b.is_nan()), "{h:#06x}: {a} vs {b}");
        }
    }

    #[test]
    fn fused_accumulate_matches_three_step() {
        let mut rng = crate::util::rng::Pcg32::new(5);
        let enc: Vec<u16> = (0..1000).map(|_| f32_to_f16(rng.next_normal())).collect();
        let base: Vec<f32> = (0..1000).map(|_| rng.next_normal()).collect();
        let mut fused = base.clone();
        accumulate_quantized(&mut fused, &enc);
        let mut manual = base;
        for (d, &h) in manual.iter_mut().zip(&enc) {
            *d = quantize_f16(*d + f16_to_f32(h));
        }
        assert_eq!(fused, manual);
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-2.0), 0xC000);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16(65536.0), 0x7C00); // overflow -> inf
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f32_to_f16(5.960_464_5e-8), 0x0001); // smallest subnormal
    }

    #[test]
    fn decode_known_values() {
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0xC000), -2.0);
        assert_eq!(f16_to_f32(0x7BFF), 65504.0);
        assert_eq!(f16_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_to_f32(0x7E00).is_nan());
        assert_eq!(f16_to_f32(0x0001), 5.960_464_5e-8);
    }

    #[test]
    fn round_trip_exact_for_f16_representable() {
        // Every one of the 63488 finite f16 bit patterns must round-trip.
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan
            }
            let f = f16_to_f32(h);
            let back = f32_to_f16(f);
            // -0 and +0 have distinct patterns and must be preserved.
            assert_eq!(back, h, "pattern {h:#06x} -> {f} -> {back:#06x}");
        }
    }

    #[test]
    fn quantisation_error_bounded_half_ulp() {
        // Relative error of round-to-nearest f16 <= 2^-11 for normal range.
        let mut rng = crate::util::rng::Pcg32::new(5);
        for _ in 0..100_000 {
            let x = (rng.next_f32() - 0.5) * 100.0;
            if x.abs() < 6.2e-5 {
                continue; // skip subnormal range (absolute, not relative, bound)
            }
            let q = quantize_f16(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 4.9e-4, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and the next f16; the
        // even mantissa (1.0) must win.
        let halfway = f32::from_bits(0x3F80_1000); // 1.0 + 2^-11
        assert_eq!(f32_to_f16(halfway), 0x3C00);
        // Next representable above halfway rounds up.
        let above = f32::from_bits(0x3F80_1001);
        assert_eq!(f32_to_f16(above), 0x3C01);
    }

    #[test]
    fn slice_encode_decode() {
        let src = [0.5f32, -1.25, 3.0e4, 1.0e-7, f32::INFINITY];
        let mut enc = [0u16; 5];
        let mut dec = [0f32; 5];
        encode_slice(&src, &mut enc);
        decode_slice(&enc, &mut dec);
        assert_eq!(dec[0], 0.5);
        assert_eq!(dec[1], -1.25);
        assert!((dec[2] - 3.0e4).abs() / 3.0e4 < 5e-4);
        assert_eq!(dec[4], f32::INFINITY);
    }
}
