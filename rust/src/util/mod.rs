//! Foundation utilities shared across the stack.
//!
//! Everything here exists because the offline crate registry ships only the
//! `xla` crate's closure: no `rand`, `serde`, `half`, `proptest`, or
//! `criterion`. Each submodule is a focused, tested replacement for exactly
//! the slice of functionality this project needs.

pub mod half;
pub mod json;
pub mod plot;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod toml;
