//! Minimal TOML-subset parser for run configuration files.
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / homogeneous-array values, `#` comments.
//! This covers everything `configs/*.toml` uses; anything else is a parse
//! error (fail-fast beats silently mis-reading a training config).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(x) => Ok(*x),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_i64()?;
        usize::try_from(x).map_err(|_| anyhow!("expected non-negative integer, got {x}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(x) => Ok(*x),
            Value::Int(x) => Ok(*x as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }
}

/// A parsed document: dotted-section-qualified keys → values.
/// `[a.b]\nc = 1` is stored under key `"a.b.c"`.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if map.insert(full.clone(), value).is_some() {
                bail!("line {}: duplicate key {full:?}", lineno + 1);
            }
        }
        Ok(Doc { map })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn require(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing config key {key:?}"))
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.as_usize(),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.as_f64(),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }

    /// All keys under `prefix.` (used to enumerate schedule phases).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.map
            .keys()
            .filter(move |k| k.starts_with(&want))
            .map(|k| k.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        if !rest[end + 1..].trim().is_empty() {
            bail!("trailing garbage after string");
        }
        return Ok(Value::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut vals = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                vals.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(vals));
    }
    // Number: int if it parses as i64 and has no '.', 'e'.
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(x) = s.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(x));
        }
    }
    if let Ok(x) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(x));
    }
    bail!("cannot parse value {s:?}")
}

/// Split a flat array body on commas (no nested arrays in our configs).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = Doc::parse(
            r#"
# run config
name = "exp2"
steps = 300

[cluster]
ranks = 8
grid = [2, 4]

[sched.lr]
kind = "config_b"
base = 29.0
warmup_epochs = 5
"#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "exp2");
        assert_eq!(doc.get("steps").unwrap().as_i64().unwrap(), 300);
        assert_eq!(doc.get("cluster.ranks").unwrap().as_usize().unwrap(), 8);
        let grid = doc.get("cluster.grid").unwrap().as_arr().unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[1].as_i64().unwrap(), 4);
        assert_eq!(doc.get("sched.lr.base").unwrap().as_f64().unwrap(), 29.0);
    }

    #[test]
    fn comments_and_strings() {
        let doc = Doc::parse("s = \"a # b\" # real comment\n").unwrap();
        assert_eq!(doc.get("s").unwrap().as_str().unwrap(), "a # b");
    }

    #[test]
    fn int_float_bool() {
        let doc = Doc::parse("a = 1\nb = 1.5\nc = true\nd = -3\ne = 1e-4\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(doc.get("b").unwrap().as_f64().unwrap(), 1.5);
        assert!(doc.get("c").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("d").unwrap().as_i64().unwrap(), -3);
        assert_eq!(doc.get("e").unwrap().as_f64().unwrap(), 1e-4);
        // ints coerce to f64 on demand
        assert_eq!(doc.get("a").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(Doc::parse("[unclosed\n").is_err());
        assert!(Doc::parse("novalue\n").is_err());
        assert!(Doc::parse("k = \n").is_err());
        assert!(Doc::parse("k = 1\nk = 2\n").is_err());
    }

    #[test]
    fn defaults() {
        let doc = Doc::parse("x = 5\n").unwrap();
        assert_eq!(doc.usize_or("x", 1).unwrap(), 5);
        assert_eq!(doc.usize_or("y", 7).unwrap(), 7);
        assert_eq!(doc.f64_or("z", 0.5).unwrap(), 0.5);
        assert_eq!(doc.str_or("s", "d").unwrap(), "d");
        assert!(doc.bool_or("b", true).unwrap());
    }

    #[test]
    fn keys_under_prefix() {
        let doc = Doc::parse("[p]\na = 1\nb = 2\n[q]\nc = 3\n").unwrap();
        let keys: Vec<&str> = doc.keys_under("p").collect();
        assert_eq!(keys, vec!["p.a", "p.b"]);
    }
}
