//! Bench timing helpers (the offline registry has no criterion).
//!
//! `bench()` runs warmup + timed iterations and reports mean/stddev/p50/p95;
//! used by every target in `rust/benches/` (all declared `harness = false`).

use std::time::Instant;

use super::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Pretty one-line summary: `name  mean ± sd  [p50 p95]`.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  p50 {:>12}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        stddev_ns: stats::stddev(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p95_ns: stats::percentile(&samples, 95.0),
        min_ns: stats::min(&samples),
    }
}

/// Time `f` adaptively: enough iterations to spend ~`target_ms` total,
/// bounded to `[min_iters, max_iters]`.
pub fn bench_adaptive(name: &str, target_ms: f64, mut f: impl FnMut()) -> BenchResult {
    // One calibration run.
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target_ms * 1e6 / once_ns) as usize).clamp(3, 1000);
    bench(name, 1, iters, f)
}

/// A wall-clock stopwatch with named laps (step-time breakdowns).
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self {
            start: now,
            last: now,
            laps: Vec::new(),
        }
    }

    /// Record time since the previous lap under `name`; returns seconds.
    pub fn lap(&mut self, name: &str) -> f64 {
        let now = Instant::now();
        let secs = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.laps.push((name.to_string(), secs));
        secs
    }

    pub fn total_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Sum of laps recorded under `name`.
    pub fn lap_total(&self, name: &str) -> f64 {
        self.laps
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, s)| s)
            .sum()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 2, 16, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 16);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns + 1.0);
        assert!(r.min_ns <= r.mean_ns + 1.0);
        assert!(r.line().contains("noop-ish"));
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a = sw.lap("x");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = sw.lap("x");
        assert!(a > 0.0 && b > 0.0);
        assert!((sw.lap_total("x") - (a + b)).abs() < 1e-9);
        assert!(sw.total_secs() >= a + b);
    }
}
