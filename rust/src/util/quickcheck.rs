//! Mini property-testing harness (the offline registry has no proptest).
//!
//! Deterministic: each case derives from a fixed master seed, so failures
//! reproduce exactly. On failure the case index and seed are reported; no
//! shrinking (cases are kept small by construction instead).
//!
//! ```ignore
//! prop(|g| {
//!     let n = g.usize_in(1..=1000);
//!     let xs = g.vec_f32(n, -10.0..10.0);
//!     // ... assert invariant ...
//! });
//! ```

use super::rng::Pcg32;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg32,
    /// Seed of this case (printed on panic for reproduction).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed),
            seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u32) as usize
    }

    pub fn f32_in(&mut self, range: std::ops::Range<f32>) -> f32 {
        self.rng.range_f32(range.start, range.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.next_normal()
    }

    pub fn vec_f32(&mut self, n: usize, range: std::ops::Range<f32>) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(range.clone())).collect()
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.next_below(xs.len() as u32) as usize]
    }
}

/// Run `cases` instances of the property with seeds derived from `master`.
pub fn prop_seeded(master: u64, cases: usize, mut f: impl FnMut(&mut Gen)) {
    let mut seeder = super::rng::SplitMix64::new(master);
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case}/{cases}, seed {seed:#018x} \
                 (reproduce with Gen::new({seed:#x}))"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Run with the default master seed and case count.
pub fn prop(f: impl FnMut(&mut Gen)) {
    prop_seeded(0xF1A5_46D0_5EED, DEFAULT_CASES, f);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases_deterministically() {
        let mut seen_a = Vec::new();
        prop_seeded(1, 10, |g| seen_a.push(g.u64()));
        let mut seen_b = Vec::new();
        prop_seeded(1, 10, |g| seen_b.push(g.u64()));
        assert_eq!(seen_a, seen_b);
        assert_eq!(seen_a.len(), 10);
    }

    #[test]
    fn ranges_respected() {
        prop(|g| {
            let n = g.usize_in(3..=7);
            assert!((3..=7).contains(&n));
            let x = g.f32_in(-1.0..1.0);
            assert!((-1.0..1.0).contains(&x));
            let v = g.vec_f32(n, 0.0..2.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|x| (0.0..2.0).contains(x)));
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        prop_seeded(2, 20, |g| {
            assert!(g.usize_in(0..=9) < 9, "intentional failure");
        });
    }
}
