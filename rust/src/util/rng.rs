//! Deterministic, seedable PRNGs (SplitMix64 + PCG32).
//!
//! Everything random in the Rust layer — synthetic data, augmentation,
//! shard shuffling, property-test case generation — flows through these so
//! that any run is reproducible from a single `u64` seed. (The offline
//! registry has no `rand` crate; these are the standard reference
//! implementations.)

/// SplitMix64 — used for seeding and for cheap stateless streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — the main generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create from a seed; stream is derived from the seed via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::with_stream(sm.next_u64(), sm.next_u64())
    }

    /// Create with an explicit stream id (distinct streams never collide).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection, unbiased).
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Known-good first outputs for seed 1234567 (reference impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn pcg_deterministic_and_stream_separated() {
        let mut a = Pcg32::with_stream(42, 1);
        let mut b = Pcg32::with_stream(42, 2);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(xs, ys);
        let mut a2 = Pcg32::with_stream(42, 1);
        let xs2: Vec<u32> = (0..8).map(|_| a2.next_u32()).collect();
        assert_eq!(xs, xs2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_coverage() {
        let mut rng = Pcg32::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[rng.next_below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Pcg32::new(11);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = rng.next_normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
