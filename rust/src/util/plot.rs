//! Tiny ASCII line plots for terminal output (loss curves, efficiency
//! curves in the benches and examples — no plotting crates offline).

/// Render `series` (x, y) as an ASCII plot of `width`×`height` chars.
/// Points are bucketed by x; each bucket plots its mean y.
pub fn line_plot(series: &[(f64, f64)], width: usize, height: usize, title: &str) -> String {
    if series.is_empty() || width < 8 || height < 2 {
        return format!("{title}: (no data)\n");
    }
    let xs: Vec<f64> = series.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = series.iter().map(|p| p.1).collect();
    let (xmin, xmax) = (min(&xs), max(&xs));
    let (mut ymin, mut ymax) = (min(&ys), max(&ys));
    if (ymax - ymin).abs() < 1e-12 {
        ymin -= 0.5;
        ymax += 0.5;
    }

    // bucket by x
    let mut sums = vec![0.0f64; width];
    let mut counts = vec![0usize; width];
    for &(x, y) in series {
        let t = if xmax > xmin { (x - xmin) / (xmax - xmin) } else { 0.0 };
        let col = ((t * (width - 1) as f64).round() as usize).min(width - 1);
        sums[col] += y;
        counts[col] += 1;
    }

    let mut grid = vec![vec![' '; width]; height];
    let mut last_row: Option<usize> = None;
    for col in 0..width {
        if counts[col] == 0 {
            continue;
        }
        let y = sums[col] / counts[col] as f64;
        let t = (y - ymin) / (ymax - ymin);
        let row = height - 1 - ((t * (height - 1) as f64).round() as usize).min(height - 1);
        grid[row][col] = '*';
        // connect vertically to the previous column for readability
        if let Some(prev) = last_row {
            let (lo, hi) = if prev < row { (prev, row) } else { (row, prev) };
            for r in lo + 1..hi {
                if grid[r][col] == ' ' {
                    grid[r][col] = '|';
                }
            }
        }
        last_row = Some(row);
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>9.3} ")
        } else if i == height - 1 {
            format!("{ymin:>9.3} ")
        } else {
            " ".repeat(10)
        };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(10));
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "{}{:<.6}{}{:>.6}\n",
        " ".repeat(11),
        xmin,
        " ".repeat(width.saturating_sub(14)),
        xmax
    ));
    out
}

fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_descending_curve() {
        let series: Vec<(f64, f64)> = (0..100)
            .map(|i| (i as f64, 2.5 - 0.02 * i as f64))
            .collect();
        let p = line_plot(&series, 40, 8, "loss");
        assert!(p.starts_with("loss\n"));
        assert!(p.contains('*'));
        // top-left should contain the max label, bottom the min
        assert!(p.contains("2.500"));
        assert!(p.contains("0.520"));
        let lines: Vec<&str> = p.lines().collect();
        assert_eq!(lines.len(), 8 + 3);
    }

    #[test]
    fn handles_flat_and_empty() {
        let flat: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 1.0)).collect();
        let p = line_plot(&flat, 20, 4, "flat");
        assert!(p.contains('*'));
        assert!(line_plot(&[], 20, 4, "none").contains("no data"));
    }

    #[test]
    fn single_point() {
        let p = line_plot(&[(1.0, 5.0)], 20, 4, "pt");
        assert!(p.contains('*'));
    }
}
