//! Small statistics helpers for metrics and the bench harness.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). Returns 0.0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy. `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordinary least squares y = a + b x. Returns (intercept, slope).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least 2 points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = num / den;
    (my - slope * mx, slope)
}

/// Exponential moving average over a series (smoothing for loss curves).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ema_smooths() {
        let xs = [1.0, 1.0, 10.0, 1.0];
        let e = ema(&xs, 0.5);
        assert_eq!(e[0], 1.0);
        assert_eq!(e[1], 1.0);
        assert_eq!(e[2], 5.5);
        assert_eq!(e[3], 3.25);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
