//! Minimal JSON parser + emitter (the offline registry has no serde).
//!
//! Scope: exactly what `artifacts/manifest.json` and the metrics emitters
//! need — objects, arrays, strings (with escapes), numbers, bools, null.
//! Strict on structure, permissive on whitespace. Numbers parse as `f64`
//! (the manifest only contains integers that fit exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?} (have {:?})", m.keys().collect::<Vec<_>>())),
            _ => bail!("expected object while looking up {key:?}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > 2f64.powi(53) {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    /// Compact serialisation (stable: object keys already sorted by BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.bytes[self.pos] as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("invalid \\u{code:04x}"))?,
                            );
                        }
                        e => bail!("invalid escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: find the full char from the source.
                    let start = self.pos - 1;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|e| anyhow!("bad utf8 in string: {e}"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "x");
        assert!(j.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn parses_utf8_strings() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ✓");
    }
}
