//! Image augmentation (paper §3.2 lists padding/flip/crop/brightness/
//! contrast/noise among NNL's pipeline; we implement the core subset that
//! affects the reduced-scale convergence runs).
//!
//! All ops are deterministic per `(seed, epoch, sample-index)` so any
//! worker reproduces any augmented sample bit-for-bit.

use crate::util::rng::Pcg32;

/// Augmentation policy.
#[derive(Debug, Clone)]
pub struct Augment {
    pub seed: u64,
    /// Pad-and-crop radius in pixels (paper-style random crop).
    pub crop_pad: usize,
    pub hflip: bool,
    /// Max |brightness| shift (additive).
    pub brightness: f32,
    /// Max contrast deviation from 1.0 (multiplicative).
    pub contrast: f32,
}

impl Augment {
    /// Default policy for the reduced-scale twins.
    pub fn standard(seed: u64) -> Self {
        Self {
            seed,
            crop_pad: 2,
            hflip: true,
            brightness: 0.2,
            contrast: 0.2,
        }
    }

    /// No-op policy (eval path).
    pub fn none() -> Self {
        Self {
            seed: 0,
            crop_pad: 0,
            hflip: false,
            brightness: 0.0,
            contrast: 0.0,
        }
    }

    /// Apply in place to one HWC image of side `size` / `channels`.
    pub fn apply(&self, img: &mut [f32], size: usize, channels: usize, epoch: u32, index: u64) {
        assert_eq!(img.len(), size * size * channels);
        if self.crop_pad == 0 && !self.hflip && self.brightness == 0.0 && self.contrast == 0.0 {
            return;
        }
        let stream = (epoch as u64) << 40 ^ index;
        let mut rng = Pcg32::with_stream(self.seed ^ 0xA06_3E27, stream);

        if self.hflip && rng.next_f32() < 0.5 {
            hflip(img, size, channels);
        }
        if self.crop_pad > 0 {
            let p = self.crop_pad as i32;
            let dy = rng.next_below((2 * p + 1) as u32) as i32 - p;
            let dx = rng.next_below((2 * p + 1) as u32) as i32 - p;
            shift(img, size, channels, dy, dx);
        }
        if self.brightness > 0.0 {
            let b = rng.range_f32(-self.brightness, self.brightness);
            for v in img.iter_mut() {
                *v += b;
            }
        }
        if self.contrast > 0.0 {
            let c = 1.0 + rng.range_f32(-self.contrast, self.contrast);
            let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
            for v in img.iter_mut() {
                *v = mean + (*v - mean) * c;
            }
        }
    }
}

/// Horizontal mirror in place.
fn hflip(img: &mut [f32], size: usize, channels: usize) {
    for y in 0..size {
        for x in 0..size / 2 {
            let xr = size - 1 - x;
            for c in 0..channels {
                img.swap((y * size + x) * channels + c, (y * size + xr) * channels + c);
            }
        }
    }
}

/// Translate by (dy, dx) with zero padding (equivalent to pad+crop).
fn shift(img: &mut [f32], size: usize, channels: usize, dy: i32, dx: i32) {
    if dy == 0 && dx == 0 {
        return;
    }
    let src = img.to_vec();
    img.iter_mut().for_each(|v| *v = 0.0);
    for y in 0..size as i32 {
        let sy = y - dy;
        if sy < 0 || sy >= size as i32 {
            continue;
        }
        for x in 0..size as i32 {
            let sx = x - dx;
            if sx < 0 || sx >= size as i32 {
                continue;
            }
            for c in 0..channels {
                img[((y as usize) * size + x as usize) * channels + c] =
                    src[((sy as usize) * size + sx as usize) * channels + c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(size: usize, channels: usize) -> Vec<f32> {
        (0..size * size * channels).map(|i| i as f32).collect()
    }

    #[test]
    fn deterministic() {
        let aug = Augment::standard(1);
        let mut a = ramp(8, 3);
        let mut b = ramp(8, 3);
        aug.apply(&mut a, 8, 3, 2, 5);
        aug.apply(&mut b, 8, 3, 2, 5);
        assert_eq!(a, b);
        let mut c = ramp(8, 3);
        aug.apply(&mut c, 8, 3, 2, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn none_is_identity() {
        let aug = Augment::none();
        let mut a = ramp(8, 3);
        aug.apply(&mut a, 8, 3, 0, 0);
        assert_eq!(a, ramp(8, 3));
    }

    #[test]
    fn hflip_involution() {
        let mut a = ramp(6, 2);
        hflip(&mut a, 6, 2);
        let flipped = a.clone();
        hflip(&mut a, 6, 2);
        assert_eq!(a, ramp(6, 2));
        assert_ne!(flipped, ramp(6, 2));
    }

    #[test]
    fn shift_moves_content() {
        let size = 4;
        let mut a = vec![0.0f32; 16];
        a[0] = 1.0; // top-left pixel
        shift(&mut a, size, 1, 1, 1);
        assert_eq!(a[(1 * size + 1) * 1], 1.0);
        assert_eq!(a[0], 0.0);
    }

    #[test]
    fn shift_zero_pads_at_border() {
        let size = 4;
        let mut a = vec![1.0f32; 16];
        shift(&mut a, size, 1, 2, 0);
        // top two rows are padding now
        assert!(a[..8].iter().all(|&v| v == 0.0));
        assert!(a[8..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn brightness_contrast_bounded() {
        let aug = Augment {
            seed: 3,
            crop_pad: 0,
            hflip: false,
            brightness: 0.1,
            contrast: 0.0,
        };
        let mut a = vec![0.5f32; 27];
        aug.apply(&mut a, 3, 3, 0, 0);
        for &v in &a {
            assert!((v - 0.5).abs() <= 0.1 + 1e-6);
        }
    }
}
