//! Deterministic per-epoch sharding of the training set across workers.
//!
//! Every epoch gets a fresh global permutation (seeded by `(seed, epoch)`);
//! each worker takes a contiguous slice. All workers can compute the whole
//! assignment independently — no shard server, no communication — which is
//! how the paper's input pipeline scales to thousands of GPUs.

use crate::util::rng::Pcg32;

/// Sharding plan for one epoch.
#[derive(Debug, Clone)]
pub struct EpochShards {
    perm: Vec<u32>,
    workers: usize,
}

impl EpochShards {
    /// Build the epoch permutation. `dataset_size` must fit in u32.
    pub fn new(seed: u64, epoch: u32, dataset_size: usize, workers: usize) -> Self {
        assert!(workers > 0);
        assert!(dataset_size < u32::MAX as usize);
        let mut perm: Vec<u32> = (0..dataset_size as u32).collect();
        let mut rng = Pcg32::with_stream(seed ^ 0x5AAD, epoch as u64);
        rng.shuffle(&mut perm);
        Self { perm, workers }
    }

    /// Global sample indices assigned to `rank` (contiguous slice of the
    /// permutation; sizes differ by at most 1 across ranks).
    pub fn for_rank(&self, rank: usize) -> &[u32] {
        assert!(rank < self.workers);
        let n = self.perm.len();
        let base = n / self.workers;
        let rem = n % self.workers;
        let start = rank * base + rank.min(rem);
        let len = base + usize::from(rank < rem);
        &self.perm[start..start + len]
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shards_partition_dataset() {
        let s = EpochShards::new(1, 0, 1000, 7);
        let mut seen = HashSet::new();
        let mut total = 0;
        for r in 0..7 {
            let shard = s.for_rank(r);
            total += shard.len();
            for &i in shard {
                assert!(seen.insert(i), "index {i} assigned twice");
            }
        }
        assert_eq!(total, 1000);
        assert_eq!(seen.len(), 1000);
    }

    #[test]
    fn balanced_within_one() {
        let s = EpochShards::new(1, 0, 1003, 8);
        let sizes: Vec<usize> = (0..8).map(|r| s.for_rank(r).len()).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let a0 = EpochShards::new(9, 0, 500, 4);
        let a0b = EpochShards::new(9, 0, 500, 4);
        let a1 = EpochShards::new(9, 1, 500, 4);
        assert_eq!(a0.for_rank(0), a0b.for_rank(0));
        assert_ne!(a0.for_rank(0), a1.for_rank(0));
    }

    #[test]
    fn different_seeds_differ() {
        let a = EpochShards::new(1, 0, 100, 2);
        let b = EpochShards::new(2, 0, 100, 2);
        assert_ne!(a.for_rank(0), b.for_rank(0));
    }
}
