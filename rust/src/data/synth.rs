//! Synthetic ImageNet stand-in (DESIGN.md §4 substitution).
//!
//! A deterministic, class-conditional image generator: every class gets a
//! smooth random "prototype" pattern (a coarse grid bilinearly upsampled —
//! low-frequency structure a conv net can latch onto); each sample is its
//! class prototype plus per-sample Gaussian noise. The task is genuinely
//! learnable (so loss curves and the LS/BSC ablations are meaningful) while
//! every byte is reproducible from `(seed, index)` — no data files, any
//! worker can materialise any sample, which is what makes deterministic
//! sharding across thousands of simulated workers trivial.

use crate::util::rng::{Pcg32, SplitMix64};

/// Dataset geometry + generation parameters.
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub seed: u64,
    pub num_classes: usize,
    pub image_size: usize,
    pub channels: usize,
    pub train_size: usize,
    pub val_size: usize,
    /// Per-sample noise stddev (higher = harder task).
    pub noise: f32,
    /// Coarse prototype grid edge (low-frequency content scale).
    proto_grid: usize,
    /// Cached class prototypes, row-major [class][h*w*c].
    prototypes: Vec<Vec<f32>>,
}

impl SynthDataset {
    pub fn new(
        seed: u64,
        num_classes: usize,
        image_size: usize,
        channels: usize,
        train_size: usize,
        val_size: usize,
    ) -> Self {
        let proto_grid = 4;
        let mut ds = Self {
            seed,
            num_classes,
            image_size,
            channels,
            train_size,
            val_size,
            noise: 0.6,
            proto_grid,
            prototypes: Vec::new(),
        };
        ds.prototypes = (0..num_classes).map(|c| ds.make_prototype(c)).collect();
        ds
    }

    /// CIFAR-shaped default: 10 classes of 32×32×3.
    pub fn cifar_like(seed: u64) -> Self {
        Self::new(seed, 10, 32, 3, 50_000, 10_000)
    }

    /// Tiny twin matching the `tiny` model arch (16×16×3, 10 classes).
    pub fn tiny(seed: u64) -> Self {
        Self::new(seed, 10, 16, 3, 4_096, 1_024)
    }

    pub fn pixels(&self) -> usize {
        self.image_size * self.image_size * self.channels
    }

    /// Low-frequency class prototype: coarse grid → bilinear upsample.
    fn make_prototype(&self, class: usize) -> Vec<f32> {
        let g = self.proto_grid;
        let mut rng = Pcg32::with_stream(self.seed ^ 0xC1A5_5000, class as u64);
        let coarse: Vec<f32> = (0..g * g * self.channels)
            .map(|_| rng.next_normal() * 1.5)
            .collect();
        let s = self.image_size;
        let mut img = vec![0.0f32; self.pixels()];
        for y in 0..s {
            for x in 0..s {
                // continuous coarse coordinates
                let fy = y as f32 / s as f32 * (g - 1) as f32;
                let fx = x as f32 / s as f32 * (g - 1) as f32;
                let (y0, x0) = (fy as usize, fx as usize);
                let (y1, x1) = ((y0 + 1).min(g - 1), (x0 + 1).min(g - 1));
                let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                for c in 0..self.channels {
                    let v00 = coarse[(y0 * g + x0) * self.channels + c];
                    let v01 = coarse[(y0 * g + x1) * self.channels + c];
                    let v10 = coarse[(y1 * g + x0) * self.channels + c];
                    let v11 = coarse[(y1 * g + x1) * self.channels + c];
                    let v0 = v00 * (1.0 - dx) + v01 * dx;
                    let v1 = v10 * (1.0 - dx) + v11 * dx;
                    img[(y * s + x) * self.channels + c] = v0 * (1.0 - dy) + v1 * dy;
                }
            }
        }
        img
    }

    /// Label of training sample `index` (balanced round-robin).
    pub fn train_label(&self, index: usize) -> i32 {
        debug_assert!(index < self.train_size);
        (index % self.num_classes) as i32
    }

    /// Label of validation sample `index`.
    pub fn val_label(&self, index: usize) -> i32 {
        debug_assert!(index < self.val_size);
        (index % self.num_classes) as i32
    }

    /// Materialise training sample `index` into `out` (len = pixels()).
    pub fn train_image(&self, index: usize, out: &mut [f32]) {
        self.render(index as u64, self.train_label(index) as usize, out);
    }

    /// Materialise validation sample `index` (disjoint noise stream).
    pub fn val_image(&self, index: usize, out: &mut [f32]) {
        self.render(
            index as u64 ^ 0x5A17_0000_0000,
            self.val_label(index) as usize,
            out,
        );
    }

    fn render(&self, stream: u64, class: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.pixels());
        let mut sm = SplitMix64::new(self.seed ^ 0xDA7A);
        let base = sm.next_u64();
        let mut rng = Pcg32::with_stream(base, stream);
        let proto = &self.prototypes[class];
        for (o, &p) in out.iter_mut().zip(proto) {
            *o = p + self.noise * rng.next_normal();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn deterministic_per_index() {
        let ds = SynthDataset::tiny(7);
        let mut a = vec![0.0; ds.pixels()];
        let mut b = vec![0.0; ds.pixels()];
        ds.train_image(13, &mut a);
        ds.train_image(13, &mut b);
        assert_eq!(a, b);
        ds.train_image(14, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_balanced() {
        let ds = SynthDataset::tiny(7);
        let mut counts = vec![0usize; ds.num_classes];
        for i in 0..ds.train_size {
            counts[ds.train_label(i) as usize] += 1;
        }
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn same_class_samples_correlate_more_than_cross_class() {
        let ds = SynthDataset::tiny(3);
        let n = ds.pixels();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        let mut c = vec![0.0; n];
        ds.train_image(0, &mut a); // class 0
        ds.train_image(10, &mut b); // class 0
        ds.train_image(1, &mut c); // class 1
        let dot = |x: &[f32], y: &[f32]| -> f64 {
            x.iter().zip(y).map(|(a, b)| (*a * *b) as f64).sum::<f64>()
        };
        let norm = |x: &[f32]| dot(x, x).sqrt();
        let same = dot(&a, &b) / (norm(&a) * norm(&b));
        let cross = dot(&a, &c) / (norm(&a) * norm(&c));
        assert!(
            same > cross + 0.1,
            "same-class corr {same:.3} vs cross {cross:.3}"
        );
    }

    #[test]
    fn pixel_statistics_are_sane() {
        let ds = SynthDataset::tiny(9);
        let mut img = vec![0.0; ds.pixels()];
        let mut all: Vec<f64> = Vec::new();
        for i in 0..50 {
            ds.train_image(i, &mut img);
            all.extend(img.iter().map(|&x| x as f64));
        }
        let m = stats::mean(&all);
        let sd = stats::stddev(&all);
        assert!(m.abs() < 0.5, "mean {m}");
        assert!(sd > 0.5 && sd < 3.0, "std {sd}");
    }

    #[test]
    fn val_and_train_streams_disjoint() {
        let ds = SynthDataset::tiny(5);
        let mut a = vec![0.0; ds.pixels()];
        let mut b = vec![0.0; ds.pixels()];
        ds.train_image(0, &mut a);
        ds.val_image(0, &mut b);
        assert_ne!(a, b);
    }
}
