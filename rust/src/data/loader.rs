//! Batched host-side loader: shard → synth → augment → NHWC f32 buffers.
//!
//! One `Loader` per worker thread. Batches are materialised straight into
//! reusable buffers shaped for the `grad_step` artifact's `images`/`labels`
//! inputs; no allocation in the steady state.

use super::augment::Augment;
use super::shard::EpochShards;
use super::synth::SynthDataset;

/// One materialised training batch (NHWC images + int labels).
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch_size: usize,
}

/// Per-rank training-data loader.
pub struct Loader {
    dataset: SynthDataset,
    augment: Augment,
    rank: usize,
    workers: usize,
    epoch: u32,
    cursor: usize,
    shards: EpochShards,
}

impl Loader {
    pub fn new(dataset: SynthDataset, augment: Augment, rank: usize, workers: usize) -> Self {
        let shards = EpochShards::new(dataset.seed, 0, dataset.train_size, workers);
        Self {
            dataset,
            augment,
            rank,
            workers,
            epoch: 0,
            cursor: 0,
            shards,
        }
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    pub fn dataset(&self) -> &SynthDataset {
        &self.dataset
    }

    /// Reconfigure the worker pool (batch-size-control phase switches can
    /// change the worker count); restarts the current epoch's shard plan.
    pub fn reshard(&mut self, rank: usize, workers: usize) {
        self.rank = rank;
        self.workers = workers;
        self.shards = EpochShards::new(self.dataset.seed, self.epoch, self.dataset.train_size, workers);
        self.cursor = 0;
    }

    /// Fill `out` with the next batch of `batch` samples, wrapping to the
    /// next epoch when the shard is exhausted. Returns the epoch the batch
    /// came from.
    pub fn next_batch(&mut self, batch: usize, out: &mut Batch) -> u32 {
        let px = self.dataset.pixels();
        out.batch_size = batch;
        out.images.resize(batch * px, 0.0);
        out.labels.resize(batch, 0);
        let size = self.dataset.image_size;
        let ch = self.dataset.channels;
        let mut produced_epoch = self.epoch;
        for b in 0..batch {
            let shard = self.shards.for_rank(self.rank);
            if self.cursor >= shard.len() {
                self.epoch += 1;
                self.shards = EpochShards::new(
                    self.dataset.seed,
                    self.epoch,
                    self.dataset.train_size,
                    self.workers,
                );
                self.cursor = 0;
            }
            if b == 0 {
                produced_epoch = self.epoch;
            }
            let idx = self.shards.for_rank(self.rank)[self.cursor] as usize;
            self.cursor += 1;
            let img = &mut out.images[b * px..(b + 1) * px];
            self.dataset.train_image(idx, img);
            self.augment.apply(img, size, ch, self.epoch, idx as u64);
            out.labels[b] = self.dataset.train_label(idx);
        }
        produced_epoch
    }

    /// Jump to the start of `epoch` (phase handoff: a new phase's loader
    /// begins at the epoch where the previous phase stopped, rather than
    /// replaying epoch 0's data).
    pub fn seek_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
        self.shards = EpochShards::new(
            self.dataset.seed,
            epoch,
            self.dataset.train_size,
            self.workers,
        );
        self.cursor = 0;
    }

    /// Position the stream exactly as if this rank had already consumed
    /// `samples` samples from epoch 0 — the exact `(epoch, intra-epoch
    /// offset)` a continuing phase must resume from. Walks the actual
    /// per-epoch shard lengths, so it lands on the same `(epoch, cursor)`
    /// that consuming `samples` samples one batch at a time would reach
    /// (epochs wrap mid-batch on uneven shard sizes, and this accounts for
    /// that). Replaces the truncate-to-epoch-start seek that made a phase
    /// starting mid-epoch replay already-consumed samples.
    pub fn seek_samples(&mut self, samples: u64) {
        self.seek_epoch(0);
        let mut remaining = samples;
        loop {
            let len = self.shards.for_rank(self.rank).len() as u64;
            if len == 0 {
                // rank has no data at this worker count; nothing to seek
                return;
            }
            if remaining < len {
                self.cursor = remaining as usize;
                return;
            }
            remaining -= len;
            self.epoch += 1;
            self.shards = EpochShards::new(
                self.dataset.seed,
                self.epoch,
                self.dataset.train_size,
                self.workers,
            );
        }
    }

    /// Fast-forward past one batch without materialising it (checkpoint
    /// resume). Mirrors `next_batch`'s cursor/epoch accounting exactly so
    /// a resumed run sees the identical sample sequence.
    pub fn skip_batch(&mut self, batch: usize) {
        for _ in 0..batch {
            let shard_len = self.shards.for_rank(self.rank).len();
            if self.cursor >= shard_len {
                self.epoch += 1;
                self.shards = EpochShards::new(
                    self.dataset.seed,
                    self.epoch,
                    self.dataset.train_size,
                    self.workers,
                );
                self.cursor = 0;
            }
            self.cursor += 1;
        }
    }

    /// Fill an eval batch from the validation split (no augmentation).
    /// `start` is the first validation index; wraps around.
    pub fn val_batch(&self, start: usize, batch: usize, out: &mut Batch) {
        let px = self.dataset.pixels();
        out.batch_size = batch;
        out.images.resize(batch * px, 0.0);
        out.labels.resize(batch, 0);
        for b in 0..batch {
            let idx = (start + b) % self.dataset.val_size;
            let img = &mut out.images[b * px..(b + 1) * px];
            self.dataset.val_image(idx, img);
            out.labels[b] = self.dataset.val_label(idx);
        }
    }
}

impl Batch {
    pub fn empty() -> Self {
        Self {
            images: Vec::new(),
            labels: Vec::new(),
            batch_size: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_loader(rank: usize, workers: usize) -> Loader {
        Loader::new(
            SynthDataset::tiny(11),
            Augment::standard(11),
            rank,
            workers,
        )
    }

    #[test]
    fn batches_have_right_shape() {
        let mut l = tiny_loader(0, 2);
        let mut b = Batch::empty();
        let epoch = l.next_batch(8, &mut b);
        assert_eq!(epoch, 0);
        assert_eq!(b.images.len(), 8 * 16 * 16 * 3);
        assert_eq!(b.labels.len(), 8);
        assert!(b.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn epoch_advances_when_shard_exhausted() {
        let mut l = tiny_loader(0, 2);
        let shard_len = 4096 / 2;
        let mut b = Batch::empty();
        let mut steps = 0;
        while l.epoch() == 0 {
            l.next_batch(64, &mut b);
            steps += 1;
            assert!(steps < 100, "epoch never advanced");
        }
        assert_eq!(steps, shard_len / 64 + 1); // first batch of epoch 1
    }

    #[test]
    fn ranks_see_disjoint_data_within_epoch() {
        let mut l0 = tiny_loader(0, 2);
        let mut l1 = tiny_loader(1, 2);
        let mut b0 = Batch::empty();
        let mut b1 = Batch::empty();
        l0.next_batch(32, &mut b0);
        l1.next_batch(32, &mut b1);
        assert_ne!(b0.images, b1.images);
    }

    #[test]
    fn val_batches_deterministic_and_unaugmented() {
        let l = tiny_loader(0, 1);
        let mut a = Batch::empty();
        let mut b = Batch::empty();
        l.val_batch(0, 16, &mut a);
        l.val_batch(0, 16, &mut b);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        // wraps
        l.val_batch(1020, 8, &mut b);
        assert_eq!(b.labels.len(), 8);
    }

    #[test]
    fn skip_batch_matches_consumed_stream() {
        // skipping k batches == consuming k batches, for the next batch
        let mut consumed = tiny_loader(1, 2);
        let mut skipped = tiny_loader(1, 2);
        let mut b = Batch::empty();
        for _ in 0..40 {
            consumed.next_batch(60, &mut b); // crosses an epoch boundary
        }
        for _ in 0..40 {
            skipped.skip_batch(60);
        }
        assert_eq!(consumed.epoch(), skipped.epoch());
        let mut b1 = Batch::empty();
        let mut b2 = Batch::empty();
        consumed.next_batch(16, &mut b1);
        skipped.next_batch(16, &mut b2);
        assert_eq!(b1.labels, b2.labels);
        assert_eq!(b1.images, b2.images);
    }

    /// Regression for the phase-handoff seek bug: a phase starting
    /// mid-epoch must continue the sample stream exactly, not rewind to the
    /// epoch start. train_size=1000, 4 workers ⇒ rank-0 shard is 250
    /// samples, so 32 steps of 8 (= 256 samples) end at epoch 1, cursor 6 —
    /// a position the old `seek_epoch(truncated)` could not express.
    #[test]
    fn seek_samples_matches_consumed_stream_mid_epoch() {
        let make = || {
            Loader::new(
                SynthDataset::new(11, 10, 16, 3, 1000, 256),
                Augment::standard(11),
                0,
                4,
            )
        };
        // "single-phase" loader: consumes straight through the boundary
        let mut consumed = make();
        let mut b = Batch::empty();
        for _ in 0..32 {
            consumed.next_batch(8, &mut b);
        }
        // "second-phase" loader: seeks to the continuation point
        let mut sought = make();
        sought.seek_samples(32 * 8);
        assert_eq!(consumed.epoch(), sought.epoch());
        assert_eq!(consumed.epoch(), 1, "boundary must land mid-epoch-1");
        let mut b1 = Batch::empty();
        let mut b2 = Batch::empty();
        for _ in 0..5 {
            consumed.next_batch(16, &mut b1);
            sought.next_batch(16, &mut b2);
            assert_eq!(b1.labels, b2.labels);
            assert_eq!(b1.images, b2.images);
        }
    }

    #[test]
    fn seek_samples_zero_is_a_fresh_stream() {
        let mut a = tiny_loader(1, 2);
        let mut b = tiny_loader(1, 2);
        b.seek_samples(0);
        let mut ba = Batch::empty();
        let mut bb = Batch::empty();
        a.next_batch(16, &mut ba);
        b.next_batch(16, &mut bb);
        assert_eq!(ba.labels, bb.labels);
    }

    #[test]
    fn reshard_restarts_cleanly() {
        let mut l = tiny_loader(0, 2);
        let mut b = Batch::empty();
        l.next_batch(16, &mut b);
        l.reshard(3, 4);
        let epoch = l.next_batch(16, &mut b);
        assert_eq!(epoch, 0);
        assert_eq!(b.labels.len(), 16);
    }
}
