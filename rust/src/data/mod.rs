//! Input pipeline: synthetic dataset (ImageNet stand-in, DESIGN.md §4),
//! deterministic sharding, augmentation, and the per-worker batch loader.

pub mod augment;
pub mod loader;
pub mod shard;
pub mod synth;

pub use augment::Augment;
pub use loader::{Batch, Loader};
pub use shard::EpochShards;
pub use synth::SynthDataset;
