//! Write-ahead run journal: the durable, append-only record of a run's
//! control-plane history.
//!
//! Every run with `[checkpoint] dir` set keeps a `journal.wal` in that
//! directory. Before a control action takes effect — a phase starts, a
//! recovery re-plan is adopted, a rejoiner is admitted — the coordinator
//! appends a record describing it and **fsyncs** it; snapshot records are
//! appended (and fsynced) after the snapshot object is durably in the
//! store but *before* older snapshots are garbage-collected, so the
//! journal never names a snapshot that was not fully written and never
//! loses the name of the snapshot a GC decision depended on.
//!
//! Frame format (little-endian), reusing the checkpoint's fletcher-64:
//!
//! ```text
//! u32 body_len | body (compact JSON) | u64 fletcher64(body)
//! ```
//!
//! Replay walks frames until the end of the file or the first torn /
//! corrupt frame — a torn tail is the *expected* signature of a crash
//! mid-append, so it truncates the replay rather than failing it, and
//! re-opening for append truncates the file back to the last valid
//! frame so new records are never shadowed behind garbage.
//!
//! The first record is always [`Record::RunStart`], carrying a
//! fletcher-64 hash of the config TOML text. `--resume` refuses to
//! continue a journal whose config hash does not match the config it was
//! given — resuming under a different schedule would silently break the
//! byte-identical-replay invariant.

use std::fs::{File, OpenOptions};
use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context as _, Result};

use crate::util::json::Json;

use super::checkpoint::fletcher64;

/// File name of the journal inside the checkpoint directory.
pub const JOURNAL_FILE: &str = "journal.wal";

/// Upper bound on one record body; a corrupt length prefix is rejected
/// before any allocation (same posture as the wire codec's frame cap).
const MAX_RECORD: u32 = 1 << 20;

/// One journal record. Steps/samples are exact (they stay far below
/// 2^53, the JSON number precision limit); the config hash is a full
/// u64, so it travels as a 16-digit hex string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Record {
    /// First record of a run (and of every resumed continuation of it).
    RunStart { config_hash: u64, name: String },
    /// A phase attempt is about to start.
    PhaseStart {
        phase: usize,
        attempt: u32,
        step: u64,
        samples: u64,
        workers: usize,
    },
    /// An elastic recovery re-plan is about to be adopted.
    Recovery { phase: usize, dead: Vec<usize> },
    /// Rejoiners are about to be admitted back to full width.
    Rejoin { phase: usize, workers: usize },
    /// A snapshot object is durably in the store under `key`.
    Snapshot { step: u64, samples: u64, key: String },
    /// The run finished and wrote its final checkpoint.
    RunEnd { step: u64, samples: u64 },
}

impl Record {
    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        match self {
            Record::RunStart { config_hash, name } => {
                put("kind", Json::Str("run_start".into()));
                put("config_hash", Json::Str(format!("{config_hash:016x}")));
                put("name", Json::Str(name.clone()));
            }
            Record::PhaseStart {
                phase,
                attempt,
                step,
                samples,
                workers,
            } => {
                put("kind", Json::Str("phase_start".into()));
                put("phase", Json::Num(*phase as f64));
                put("attempt", Json::Num(*attempt as f64));
                put("step", Json::Num(*step as f64));
                put("samples", Json::Num(*samples as f64));
                put("workers", Json::Num(*workers as f64));
            }
            Record::Recovery { phase, dead } => {
                put("kind", Json::Str("recovery".into()));
                put("phase", Json::Num(*phase as f64));
                put(
                    "dead",
                    Json::Arr(dead.iter().map(|&r| Json::Num(r as f64)).collect()),
                );
            }
            Record::Rejoin { phase, workers } => {
                put("kind", Json::Str("rejoin".into()));
                put("phase", Json::Num(*phase as f64));
                put("workers", Json::Num(*workers as f64));
            }
            Record::Snapshot { step, samples, key } => {
                put("kind", Json::Str("snapshot".into()));
                put("step", Json::Num(*step as f64));
                put("samples", Json::Num(*samples as f64));
                put("key", Json::Str(key.clone()));
            }
            Record::RunEnd { step, samples } => {
                put("kind", Json::Str("run_end".into()));
                put("step", Json::Num(*step as f64));
                put("samples", Json::Num(*samples as f64));
            }
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<Record> {
        let kind = j.get("kind")?.as_str()?;
        let num = |k: &str| -> Result<u64> { Ok(j.get(k)?.as_usize()? as u64) };
        Ok(match kind {
            "run_start" => {
                let hex = j.get("config_hash")?.as_str()?;
                let config_hash = u64::from_str_radix(hex, 16)
                    .with_context(|| format!("bad config_hash {hex:?}"))?;
                Record::RunStart {
                    config_hash,
                    name: j.get("name")?.as_str()?.to_string(),
                }
            }
            "phase_start" => Record::PhaseStart {
                phase: num("phase")? as usize,
                attempt: num("attempt")? as u32,
                step: num("step")?,
                samples: num("samples")?,
                workers: num("workers")? as usize,
            },
            "recovery" => Record::Recovery {
                phase: num("phase")? as usize,
                dead: j
                    .get("dead")?
                    .as_arr()?
                    .iter()
                    .map(|r| r.as_usize())
                    .collect::<Result<Vec<_>>>()?,
            },
            "rejoin" => Record::Rejoin {
                phase: num("phase")? as usize,
                workers: num("workers")? as usize,
            },
            "snapshot" => Record::Snapshot {
                step: num("step")?,
                samples: num("samples")?,
                key: j.get("key")?.as_str()?.to_string(),
            },
            "run_end" => Record::RunEnd {
                step: num("step")?,
                samples: num("samples")?,
            },
            other => bail!("unknown journal record kind {other:?}"),
        })
    }
}

/// The result of replaying a journal file: the valid records, and the
/// byte offset of the end of the last valid frame (everything past it is
/// a torn or corrupt tail).
#[derive(Debug)]
pub struct Replay {
    pub records: Vec<Record>,
    pub valid_len: u64,
    /// True when bytes past `valid_len` were discarded.
    pub torn_tail: bool,
}

/// Decode frames from `bytes` until the end or the first invalid frame.
pub fn replay_bytes(bytes: &[u8]) -> Replay {
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        let rest = &bytes[pos..];
        if rest.len() < 4 {
            break;
        }
        let len = u32::from_le_bytes(rest[..4].try_into().unwrap());
        if len == 0 || len > MAX_RECORD {
            break;
        }
        let len = len as usize;
        if rest.len() < 4 + len + 8 {
            break; // torn mid-frame
        }
        let body = &rest[4..4 + len];
        let want = u64::from_le_bytes(rest[4 + len..4 + len + 8].try_into().unwrap());
        if fletcher64(body) != want {
            break;
        }
        let parsed = std::str::from_utf8(body)
            .ok()
            .and_then(|s| Json::parse(s).ok())
            .and_then(|j| Record::from_json(&j).ok());
        match parsed {
            Some(r) => records.push(r),
            None => break, // checksummed but unintelligible: stop, do not skip
        }
        pos += 4 + len + 8;
    }
    Replay {
        records,
        valid_len: pos as u64,
        torn_tail: pos != bytes.len(),
    }
}

/// An open journal, ready to append. Shared behind `Arc<Mutex<_>>`
/// between the coordinator loop and the background snapshotter.
#[derive(Debug)]
pub struct Journal {
    file: File,
    path: PathBuf,
}

impl Journal {
    /// Path of the journal inside a checkpoint directory.
    pub fn path_in(dir: &Path) -> PathBuf {
        dir.join(JOURNAL_FILE)
    }

    /// Replay whatever journal exists under `dir` (empty replay if none).
    pub fn replay_dir(dir: &Path) -> Result<Replay> {
        let path = Self::path_in(dir);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        Ok(replay_bytes(&bytes))
    }

    /// Open `dir`'s journal for appending, creating the directory and
    /// file if needed and truncating any torn tail left by a crash.
    /// Returns the journal and the records that were already there.
    pub fn open(dir: &Path) -> Result<(Journal, Vec<Record>)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let path = Self::path_in(dir);
        let replay = Self::replay_dir(dir)?;
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        if replay.torn_tail {
            file.set_len(replay.valid_len)
                .with_context(|| format!("truncating torn tail of {}", path.display()))?;
            file.sync_all()?;
        }
        file.seek(SeekFrom::End(0))?;
        Ok((Journal { file, path }, replay.records))
    }

    /// Append one record and fsync it. Returns only once the record is
    /// durable — callers invoke this *before* the action it describes.
    pub fn append(&mut self, rec: &Record) -> Result<()> {
        let body = rec.to_json().to_string().into_bytes();
        if body.len() as u32 > MAX_RECORD {
            bail!("journal record too large ({} bytes)", body.len());
        }
        let mut frame = Vec::with_capacity(body.len() + 12);
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        frame.extend_from_slice(&fletcher64(&body).to_le_bytes());
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to {}", self.path.display()))?;
        self.file
            .sync_data()
            .with_context(|| format!("fsyncing {}", self.path.display()))?;
        Ok(())
    }

    /// Number of records written so far this process (for `/status`,
    /// callers track counts themselves; this reads the file length as a
    /// cross-check helper in tests).
    pub fn len_bytes(&self) -> Result<u64> {
        Ok(self.file.metadata()?.len())
    }
}

/// Hash of the config TOML text, as recorded in [`Record::RunStart`].
pub fn config_hash(config_text: &str) -> u64 {
    fletcher64(config_text.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "flashsgd-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_records() -> Vec<Record> {
        vec![
            Record::RunStart {
                config_hash: 0xDEAD_BEEF_0123_4567,
                name: "smoke".into(),
            },
            Record::PhaseStart {
                phase: 0,
                attempt: 0,
                step: 0,
                samples: 0,
                workers: 4,
            },
            Record::Recovery {
                phase: 0,
                dead: vec![1, 3],
            },
            Record::Rejoin { phase: 1, workers: 4 },
            Record::Snapshot {
                step: 4,
                samples: 64,
                key: "snap-00000004.ckpt".into(),
            },
            Record::RunEnd { step: 28, samples: 448 },
        ]
    }

    #[test]
    fn append_replay_round_trip() {
        let dir = scratch("roundtrip");
        let (mut j, existing) = Journal::open(&dir).unwrap();
        assert!(existing.is_empty());
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        drop(j);

        let replay = Journal::replay_dir(&dir).unwrap();
        assert!(!replay.torn_tail);
        assert_eq!(replay.records, sample_records());

        // Re-opening returns the same records and keeps appending.
        let (mut j, records) = Journal::open(&dir).unwrap();
        assert_eq!(records, sample_records());
        j.append(&Record::RunEnd { step: 99, samples: 1 }).unwrap();
        let replay = Journal::replay_dir(&dir).unwrap();
        assert_eq!(replay.records.len(), sample_records().len() + 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let dir = scratch("torn");
        let (mut j, _) = Journal::open(&dir).unwrap();
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        drop(j);
        let path = Journal::path_in(&dir);
        let full = std::fs::read(&path).unwrap();

        // Crash mid-append: chop the last frame in half.
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        let replay = Journal::replay_dir(&dir).unwrap();
        assert!(replay.torn_tail);
        assert_eq!(replay.records.len(), sample_records().len() - 1);

        // Re-opening truncates the garbage and appends cleanly after it.
        let (mut j, records) = Journal::open(&dir).unwrap();
        assert_eq!(records.len(), sample_records().len() - 1);
        j.append(&Record::RunEnd { step: 1, samples: 2 }).unwrap();
        drop(j);
        let replay = Journal::replay_dir(&dir).unwrap();
        assert!(!replay.torn_tail);
        assert_eq!(replay.records.len(), sample_records().len());

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_frame_stops_replay() {
        let dir = scratch("corrupt");
        let (mut j, _) = Journal::open(&dir).unwrap();
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        drop(j);
        let path = Journal::path_in(&dir);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a byte in the *second* frame's body.
        let first_len = 4 + u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize + 8;
        bytes[first_len + 6] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let replay = Journal::replay_dir(&dir).unwrap();
        assert!(replay.torn_tail);
        assert_eq!(replay.records.len(), 1, "replay must stop at the corrupt frame");

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_hash_tracks_text() {
        let a = config_hash("epochs = 2");
        assert_eq!(a, config_hash("epochs = 2"));
        assert_ne!(a, config_hash("epochs = 3"));
    }

    #[test]
    fn missing_journal_replays_empty() {
        let dir = scratch("missing");
        let replay = Journal::replay_dir(&dir).unwrap();
        assert!(replay.records.is_empty());
        assert!(!replay.torn_tail);
    }
}
