//! Worker rank: one simulated GPU's training loop.
//!
//! Per phase, the rank pins its `(params, momenta)` into one **lane** of
//! the compute pool ([`ComputeClient::import_state`]) so the steady-state
//! step ships only data and gradients — never the model. Per step (the
//! paper's data-parallel structure, §2, with §2.2's comm/compute overlap):
//!   1. load the next local batch (shard of the synthetic set),
//!   2. `grad_step_streaming` against the lane-resident parameters: the
//!      lane pushes each parameter gradient over as soon as backprop
//!      finalises it (reverse layer order),
//!   3. as each tensor-aligned **bucket** of gradients completes
//!      ([`crate::collectives::BucketPlan`], `TrainConfig::bucket_bytes`),
//!      all-reduce it via the configured collective, **FP16 wire**, in its
//!      own `tag_span` window — bucket *k* reduces while the lane is still
//!      producing bucket *k+1* — then queue a per-bucket `apply_partial`
//!      (LARS is per-tensor, so bucketed apply ≡ whole-model apply
//!      bitwise). With `bucket_bytes = 0` there is a single bucket and the
//!      step degenerates to the serial grad→reduce→apply schedule,
//!      bit-identically,
//!   4. all-reduce BN stats, **FP32 wire** (paper §3.2 precision split),
//!      with the scalar step loss riding in this buffer (1 extra element)
//!      so the reported `loss_mean` is never quantised by the FP16
//!      gradient wire,
//!   5. collect the per-bucket apply replies (the lane executed them in
//!      FIFO order after the backward pass — they can never race it).
//!
//! Timing attribution: `t_compute` is time stalled waiting on the lane's
//! backward pass, `t_comm_hidden` is bucket reductions that both started
//! **and** ended while later gradients were still outstanding (comm the
//! pipeline provably hid — the conservative call, so the exposed fraction
//! is never flattered), `t_comm` is **exposed** comm — everything else,
//! plus the BN window. On the serial schedule `t_comm_hidden` is 0 and
//! the split matches the old compute-then-comm accounting.
//!
//! Parameters stay replicated: identical reduced grads + identical update
//! = identical weights on every rank. The rank exports its state only at
//! the phase boundary, where the coordinator asserts the bit-identity
//! invariant (see `coordinator::Trainer`).
//!
//! Rank 0 additionally evaluates every `eval_every` global steps (a step
//! interval, not a phase-boundary flag) against its resident parameters
//! and the synchronized running BN statistics; the other ranks simply wait
//! at the next collective, so no extra synchronisation is needed.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::collectives::{BucketPlan, BucketStaging, Collective, Transport, Wire};
use crate::config::{FaultConfig, FaultKind};
use crate::data::{Augment, Batch, Loader};
use crate::runtime::{ApplyParams, ArchManifest, ComputeClient, HostTensor};
use crate::sched::LrSchedule;
use crate::util::timer::Stopwatch;

use super::metrics::{EvalMetric, Metrics, StepMetric};

/// Typed numeric-health failure: a step's reduced loss or gradient norm
/// came back NaN/Inf. Like [`crate::collectives::MeshError`], it travels
/// through normal `Result` chains and is found with `downcast_ref`, so
/// the coordinator can distinguish "the math broke" (deterministic —
/// a replay would reproduce it, so don't burn restarts on it) from "a
/// rank died" (recoverable). All ranks observe the same reduced values,
/// so every rank raises this in lockstep — no one is left stranded in a
/// collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NonFiniteError {
    /// Rank reporting the failure (every rank reports; the reduction made
    /// the poison global, whichever rank originated it).
    pub rank: usize,
    /// Global optimizer step at which the value went non-finite.
    pub step: usize,
    /// Which quantity broke: "step loss" or "reduced gradient norm".
    pub what: &'static str,
}

impl std::fmt::Display for NonFiniteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite {} at rank {} step {} (NaN/Inf — training on garbage)",
            self.what, self.rank, self.step
        )
    }
}

impl std::error::Error for NonFiniteError {}

/// Is this failure the numeric health guard firing? Checks the typed
/// payload first; falls back to the rendered chain so the verdict
/// survives a process boundary (the remote worker ships its error as a
/// string in the `failed` frame).
pub(crate) fn error_is_non_finite(err: &anyhow::Error) -> bool {
    err.downcast_ref::<NonFiniteError>().is_some() || format!("{err:#}").contains("non-finite")
}

/// Static per-phase context shared by all workers.
pub struct PhaseCtx {
    pub arch: ArchManifest,
    pub collective: Arc<dyn Collective>,
    pub grad_wire: Wire,
    pub lr: LrSchedule,
    pub label_smoothing: f32,
    pub weight_decay: f32,
    pub per_worker_batch: usize,
    pub workers: usize,
    pub steps: usize,
    /// Global step index of this phase's first step.
    pub first_step: usize,
    /// Samples consumed before this phase (for epoch accounting).
    pub samples_before: u64,
    /// Steps of this phase already consumed by an earlier (checkpointed)
    /// run — the loader fast-forwards past their batches on entry.
    pub skip_steps: usize,
    pub dataset_size: usize,
    /// Evaluate every N global steps (0 = never inside a phase).
    pub eval_every: usize,
    /// Validation batches per evaluation.
    pub eval_batches: usize,
    /// Gradient-bucket target for the backward-overlapped reduction
    /// (`TrainConfig::bucket_bytes`; 0 = one bucket, the serial schedule).
    pub bucket_bytes: usize,
    /// Which attempt at this phase this is (0 = first; elastic recovery
    /// retries bump it). Gates deterministic fault injection.
    pub attempt: usize,
    /// Fault-tolerance knobs, including the injection hook for the
    /// deterministic chaos tests.
    pub fault: FaultConfig,
}

impl PhaseCtx {
    /// Epoch (continuous) after `samples` total processed samples.
    pub fn epoch_at(&self, samples: u64) -> f64 {
        samples as f64 / self.dataset_size as f64
    }

    /// Bare grad executable name (the session API addresses executables by
    /// exec name; the arch was fixed at `import_state`).
    pub fn grad_exec(&self) -> String {
        format!(
            "grad_b{}_ls{}",
            self.per_worker_batch,
            (self.label_smoothing * 100.0).round() as i64
        )
    }
}

/// Per-rank sample count at which this phase's stream starts: total
/// samples consumed by earlier phases (`samples_before`, minus the part of
/// *this* phase a checkpoint resume already replays via `skip_steps`),
/// divided evenly over the ranks. Exact when the worker count is unchanged
/// across the boundary; on a BSC worker-count change it is the new
/// sharding's even split of the global position (the old sharding no
/// longer exists to be continued).
pub fn phase_stream_start(
    samples_before: u64,
    skip_steps: usize,
    per_worker: usize,
    workers: usize,
) -> u64 {
    let phase_start = samples_before - (skip_steps * per_worker * workers) as u64;
    phase_start / workers as u64
}

/// Mutable per-rank state threaded through a phase.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub params: Vec<HostTensor>,
    pub momenta: Vec<HostTensor>,
    /// Running mean of the synchronized BN stats (rank 0 uses it for eval).
    pub bn_running: Vec<HostTensor>,
    pub bn_steps: u64,
}

/// Result of one rank finishing a phase.
pub struct WorkerOutput {
    pub rank: usize,
    pub state: WorkerState,
    /// Step metrics (only rank 0 fills this).
    pub metrics: Metrics,
}

/// Flatten f32 tensors into `flat` (resizing as needed); returns offsets.
pub fn flatten_into(tensors: &[HostTensor], flat: &mut Vec<f32>) -> Result<Vec<usize>> {
    let mut offsets = Vec::with_capacity(tensors.len() + 1);
    let total: usize = tensors.iter().map(|t| t.elems()).sum();
    flat.clear();
    flat.reserve(total);
    offsets.push(0);
    for t in tensors {
        flat.extend_from_slice(t.as_f32()?);
        offsets.push(flat.len());
    }
    Ok(offsets)
}

/// Scatter `flat` back into tensors shaped like `templates`. When `out`
/// already holds matching f32 tensors (the steady state of a step loop),
/// their storage is reused — no per-step allocation; otherwise the output
/// vector is rebuilt.
pub fn unflatten_from(
    flat: &[f32],
    templates: &[HostTensor],
    out: &mut Vec<HostTensor>,
) -> Result<()> {
    let reusable = out.len() == templates.len()
        && out
            .iter()
            .zip(templates)
            .all(|(o, t)| o.shape() == t.shape() && matches!(o, HostTensor::F32 { .. }));
    let mut off = 0;
    if reusable {
        for o in out.iter_mut() {
            let dst = o.as_f32_mut()?;
            let n = dst.len();
            dst.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        return Ok(());
    }
    out.clear();
    for t in templates {
        let n = t.elems();
        out.push(HostTensor::f32(
            t.shape().to_vec(),
            flat[off..off + n].to_vec(),
        ));
        off += n;
    }
    Ok(())
}

/// The one evaluation loop both eval paths share: `eval_batches`
/// validation batches through `exec_one(eval exec name, images, labels) →
/// [loss_sum, n_correct]`, normalised into an [`EvalMetric`] at `step`.
/// Rank 0's in-phase interval evals (session `eval_step` against the
/// lane-resident parameters) and the coordinator's final eval (stateless
/// `run` with caller-held parameters) differ only in the closure, so their
/// metrics can never drift apart numerically.
pub(crate) fn eval_over_val_split(
    arch: &ArchManifest,
    val_loader: &Loader,
    eval_batches: usize,
    step: usize,
    mut exec_one: impl FnMut(&str, HostTensor, HostTensor) -> Result<Vec<HostTensor>>,
) -> Result<EvalMetric> {
    let eval = arch.eval_exec()?;
    let batch = eval.batch.context("eval exec missing batch")?;
    let mut b = Batch::empty();
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for i in 0..eval_batches.max(1) {
        val_loader.val_batch(i * batch, batch, &mut b);
        let images = HostTensor::f32(
            vec![batch, arch.image_size, arch.image_size, arch.image_channels],
            b.images.clone(),
        );
        let labels = HostTensor::i32(vec![batch], b.labels.clone());
        let out = exec_one(&eval.name, images, labels)?;
        loss_sum += out[0].scalar()? as f64;
        correct += out[1].scalar()? as f64;
        total += batch;
    }
    Ok(EvalMetric {
        step,
        val_loss: loss_sum / total as f64,
        accuracy: correct / total as f64,
    })
}

/// Run one phase on one rank. `ep` is this rank's mesh endpoint (either
/// transport — the schedule only sees the trait). The rank's `(params,
/// momenta)` are moved into lane `rank % lanes` of the compute pool for
/// the duration of the phase and exported back into the returned
/// [`WorkerOutput`] at the end.
#[allow(clippy::too_many_arguments)]
pub fn run_phase(
    ctx: &PhaseCtx,
    rank: usize,
    ep: &mut dyn Transport,
    compute: &ComputeClient,
    loader: &mut Loader,
    mut state: WorkerState,
) -> Result<WorkerOutput> {
    let grad_exec = ctx.grad_exec();
    let n_bn = ctx.arch.n_bn();
    let inv_n = 1.0f32 / ctx.workers as f32;
    let mut metrics = Metrics::default();
    let mut batch = Batch::empty();
    let mut bn_flat: Vec<f32> = Vec::new();
    let mut tag: u64 = 0;
    let span = ctx.collective.tag_span(ctx.workers);

    // Bucket schedule: tensor-aligned, reverse layer order (the gradient
    // emission order), rebuilt per phase (shapes are phase-constant). The
    // staging's flat buffers and received tensors are reused every step.
    let elem_counts: Vec<usize> = ctx.arch.params.iter().map(|p| p.size).collect();
    let plan = BucketPlan::new(&elem_counts, ctx.bucket_bytes);
    let mut staging = BucketStaging::new(&plan);

    let img_shape = vec![
        ctx.per_worker_batch,
        ctx.arch.image_size,
        ctx.arch.image_size,
        ctx.arch.image_channels,
    ];

    // Phase entry: pin this rank's model state into its compute lane. From
    // here to the export below, the full parameter set never crosses the
    // channel again — steps ship batches, gradients and three scalars.
    let lane = rank % compute.lanes();
    let params = std::mem::take(&mut state.params);
    let momenta = std::mem::take(&mut state.momenta);
    let sref = compute
        .import_state(lane, &ctx.arch.name, params, momenta)
        .with_context(|| format!("rank {rank}: pinning state to lane {lane}"))?;

    // Rank 0 evaluates mid-phase; it reads validation batches through an
    // unsharded, unaugmented loader over the same dataset.
    let val_loader = if rank == 0 && ctx.eval_every > 0 {
        Some(Loader::new(loader.dataset().clone(), Augment::none(), 0, 1))
    } else {
        None
    };
    // First in-phase eval failure, surfaced only after the phase completes
    // (aborting mid-phase would strand peers inside a collective).
    let mut eval_err: Option<anyhow::Error> = None;

    // The step loop can fail mid-collective — a dead peer unwinds every
    // survivor through a `MeshError`. Run it in a closure so the error
    // path below can still clean up: queued per-bucket applies and a
    // still-streaming backward pass reply to dropped handles (the lane
    // ignores those sends), and the trailing `drop_state` is FIFO-ordered
    // behind them, leaving the lane clean for a recovery attempt.
    let steps_result: Result<()> = (|| {
        // Start this phase's data stream at the exact (epoch, intra-epoch
        // offset) where the previous phase stopped — not the truncated epoch
        // start — then, on checkpoint resume, replay past the already-trained
        // steps so the sample stream continues exactly where the saved run
        // stopped.
        loader.seek_samples(phase_stream_start(
            ctx.samples_before,
            ctx.skip_steps,
            ctx.per_worker_batch,
            ctx.workers,
        ));
        for _ in 0..ctx.skip_steps {
            loader.skip_batch(ctx.per_worker_batch);
        }

        for local_step in 0..ctx.steps {
            let mut sw = Stopwatch::new();
            let step_start = Instant::now();
            let global_step = ctx.first_step + local_step;
            // Per-step liveness tick (recv waits beat on their own; this one
            // covers the compute-heavy stretch between collectives).
            ep.heartbeat();
            // Deterministic fault injection: this rank dies here, this attempt.
            let mut poison_loss = false;
            if let Some(inj) = ctx.fault.inject {
                // Chronic slowness first: the rank survives, it just pays an
                // extra sleep every step — local work the telemetry must see.
                if let Some(ms) = inj.slow_millis(ctx.attempt, rank, global_step) {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                if inj.fires(ctx.attempt, rank, global_step) {
                    match inj.kind {
                        FaultKind::Panic => {
                            panic!("injected fault: rank {rank} panics at step {global_step}")
                        }
                        FaultKind::Hang { millis } => {
                            // Go silent long enough for the heartbeat monitor
                            // to declare this rank dead, then fail out.
                            std::thread::sleep(Duration::from_millis(millis));
                            bail!("injected fault: rank {rank} hung at step {global_step}");
                        }
                        FaultKind::Error => {
                            bail!("injected fault: rank {rank} dies at step {global_step}")
                        }
                        FaultKind::NanLoss => {
                            // Poison this rank's local loss below; the FP32
                            // reduction makes it global, and the health guard
                            // must trip on every rank.
                            poison_loss = true;
                        }
                        // Non-fatal by construction: `fires` is false for
                        // Slow (handled above via `slow_millis`).
                        FaultKind::Slow { .. } => unreachable!("Slow never fires fatally"),
                    }
                }
            }
            let samples = ctx.samples_before
                + (local_step as u64) * (ctx.per_worker_batch * ctx.workers) as u64;
            let epoch = ctx.epoch_at(samples);
            let total_batch = ctx.per_worker_batch * ctx.workers;
            let lr = ctx.lr.lr(epoch) as f32;
            let momentum = ctx.lr.momentum(epoch, total_batch) as f32;

            // 1. data
            let data_epoch = loader.next_batch(ctx.per_worker_batch, &mut batch);
            let t_data = sw.lap("data");

            // 2+3. streaming gradients with bucket-pipelined all-reduce. The
            // batch vectors move into the tensors (no clone); the lane hands
            // them back in the terminal reply so their storage is reused next
            // step.
            let images = HostTensor::f32(img_shape.clone(), std::mem::take(&mut batch.images));
            let labels = HostTensor::i32(
                vec![ctx.per_worker_batch],
                std::mem::take(&mut batch.labels),
            );
            let stream = compute
                .grad_step_streaming(&sref, &grad_exec, images, labels)
                .with_context(|| format!("rank {rank} step {global_step}: grad_step_streaming"))?;

            let hp = ApplyParams {
                lr,
                momentum,
                weight_decay: ctx.weight_decay,
            };
            staging.begin();
            let mut pending_applies = Vec::with_capacity(plan.len());
            // Numeric health: ‖reduced grad‖² accumulated in f64 across the
            // buckets — identical on every rank (the reduction is), so a
            // NaN/Inf trips the guard below on all ranks in lockstep.
            let mut grad_norm_sq = 0.0f64;
            let mut t_compute = 0.0f64; // stalled on the backward pass
            let mut t_comm = 0.0f64; // exposed communication
            let mut t_comm_hidden = 0.0f64; // reductions overlapped with backprop
            'buckets: for k in 0..plan.len() {
                // Wait for this bucket's gradients (reverse layer order means
                // buckets complete strictly in plan order). Time spent blocked
                // here is compute the pipeline could not hide.
                let wait0 = Instant::now();
                while !staging.bucket_ready(&plan, k) {
                    let Some((idx, t)) = stream.recv_grad() else {
                        // stream ended early: the terminal reply below carries
                        // the backend's actual error
                        break 'buckets;
                    };
                    staging
                        .place(&plan, idx, t)
                        .with_context(|| format!("rank {rank} step {global_step}: grad stream"))?;
                }
                // Drain whatever else backprop already produced, so the
                // hidden/exposed split below reflects the backend's progress.
                while let Some((idx, t)) = stream.try_recv_grad() {
                    staging
                        .place(&plan, idx, t)
                        .with_context(|| format!("rank {rank} step {global_step}: grad stream"))?;
                }
                t_compute += wait0.elapsed().as_secs_f64();

                // Reduce bucket k in its own tag window while the lane keeps
                // producing buckets k+1.. (hidden comm), then queue its LARS
                // update behind the stream.
                let hidden_before = !staging.all_placed(&plan);
                let red0 = Instant::now();
                let flat = staging.flat_mut(k);
                ctx.collective
                    .all_reduce(ep, flat, ctx.grad_wire, tag)
                    .with_context(|| format!("rank {rank} step {global_step}: bucket {k}"))?;
                tag += span;
                for g in flat.iter_mut() {
                    *g *= inv_n;
                    grad_norm_sq += f64::from(*g) * f64::from(*g);
                }
                let reduce_secs = red0.elapsed().as_secs_f64();
                let grads = staging.take_bucket(&plan, k)?;
                // Conservative attribution: a reduction counts as hidden only
                // if backprop was still streaming when it *ended* too (drain
                // first so the check sees the backend's real progress). A
                // reduction the stream outran mid-flight books as exposed —
                // the headline exposed-comm fraction can only be overstated,
                // never flattered.
                while let Some((idx, t)) = stream.try_recv_grad() {
                    staging
                        .place(&plan, idx, t)
                        .with_context(|| format!("rank {rank} step {global_step}: grad stream"))?;
                }
                if hidden_before && !staging.all_placed(&plan) {
                    t_comm_hidden += reduce_secs;
                } else {
                    t_comm += reduce_secs;
                }
                pending_applies.push(compute.apply_partial_async(
                    &sref,
                    plan.bucket(k).params.start,
                    grads,
                    hp,
                )?);
            }

            // Terminal reply: [loss, bn stats..] + the batch tensors back.
            let (outs, img_back, lab_back) = stream
                .finish()
                .with_context(|| format!("rank {rank} step {global_step}: grad_step_streaming"))?;
            if !staging.all_placed(&plan) {
                bail!("rank {rank} step {global_step}: gradient stream ended early");
            }
            batch.images = img_back.into_f32()?;
            batch.labels = lab_back.into_i32()?;
            let loss_local = if poison_loss {
                f32::NAN
            } else {
                outs[0].scalar()?
            };
            let bn_stats = &outs[1..1 + n_bn];

            // 4. BN-stat all-reduce (FP32 wire, paper §3.2). The scalar step
            // loss rides in this buffer — NOT in the gradient buffer — so the
            // reported loss is a pure-FP32 reduction even on the FP16 wire.
            // Nothing is left to hide behind, so this window is exposed comm.
            let bn0 = Instant::now();
            flatten_into(bn_stats, &mut bn_flat)?;
            bn_flat.push(loss_local);
            ctx.collective.all_reduce(ep, &mut bn_flat, Wire::F32, tag)?;
            tag += span;
            let loss_mean = f64::from(bn_flat.pop().unwrap()) / ctx.workers as f64;
            for s in bn_flat.iter_mut() {
                *s *= inv_n;
            }
            t_comm += bn0.elapsed().as_secs_f64();

            // Numeric health guard: a NaN/Inf in the reduced loss or the
            // reduced gradient norm means the run is training on garbage —
            // fail loudly, naming rank and step. Both quantities are
            // post-reduction and therefore identical on every rank, so all
            // ranks bail here together (after the step's last collective):
            // no peer is left blocking in a mesh that will never drain.
            if !loss_mean.is_finite() {
                return Err(NonFiniteError {
                    rank,
                    step: global_step,
                    what: "step loss",
                }
                .into());
            }
            if !grad_norm_sq.is_finite() {
                return Err(NonFiniteError {
                    rank,
                    step: global_step,
                    what: "reduced gradient norm",
                }
                .into());
            }
            // Synced-stat aggregate for the eval path. The paper's "BN without
            // moving average" uses *current* statistics; for evaluation we keep
            // a recent-weighted EMA of the cross-worker synced stats (early-
            // training stats are stale — activations rescale as params move, so
            // a uniform mean underestimates late-run variance and detonates the
            // eval forward pass).
            {
                let alpha: f32 = if state.bn_steps == 0 { 1.0 } else { 0.2 };
                let mut off = 0;
                for t in state.bn_running.iter_mut() {
                    let dst = t.as_f32_mut()?;
                    for d in dst.iter_mut() {
                        *d += alpha * (bn_flat[off] - *d);
                        off += 1;
                    }
                }
                state.bn_steps += 1;
            }

            // 5. Collect the per-bucket LARS applies. They were queued behind
            // the gradient stream, so the lane ran them strictly after the
            // backward pass finished; waiting here surfaces any error and
            // fences the step (eval/export must see the updated state).
            let apply0 = Instant::now();
            for p in pending_applies {
                p.wait()
                    .with_context(|| format!("rank {rank} step {global_step}: apply_step"))?;
            }
            let t_apply = apply0.elapsed().as_secs_f64();

            // Straggler telemetry: record this step's *local work* (elapsed
            // minus every reduction window). In a synchronous collective the
            // total step time converges to the slowest rank's pace, so only
            // the comm-excluded share identifies the culprit. Recorded before
            // rank 0's in-phase eval so eval time never inflates the EWMA.
            let work_secs =
                (step_start.elapsed().as_secs_f64() - t_comm - t_comm_hidden).max(0.0);
            ep.note_step(global_step as u64, Duration::from_secs_f64(work_secs));

            if rank == 0 {
                metrics.push(StepMetric {
                    step: global_step,
                    epoch: data_epoch,
                    loss: loss_mean,
                    lr: lr as f64,
                    momentum: momentum as f64,
                    global_batch: total_batch,
                    t_compute,
                    t_comm,
                    t_comm_hidden,
                    t_apply,
                    t_data,
                });
                // `eval_every` is a *step* interval: evaluate after every
                // N-th completed global step (recorded at the completed-step
                // count, matching the final eval's convention).
                if let Some(vl) = &val_loader {
                    let done = global_step + 1;
                    if done % ctx.eval_every == 0 {
                        let bn_running = &state.bn_running;
                        // An eval failure must not abort rank 0 mid-phase: the
                        // other ranks are already blocked in the next
                        // all-reduce and would strand the mesh (recv has no
                        // timeout). Finish the phase in lockstep and surface
                        // the error after the collectives are done.
                        match eval_over_val_split(
                            &ctx.arch,
                            vl,
                            ctx.eval_batches,
                            done,
                            |exec, images, labels| {
                                compute.eval_step(&sref, exec, bn_running, images, labels)
                            },
                        ) {
                            Ok(e) => metrics.push_eval(e),
                            Err(e) => {
                                if eval_err.is_none() {
                                    eval_err =
                                        Some(e.context(format!("rank 0 eval at step {done}")));
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    })();
    if let Err(e) = steps_result {
        // Unwind path: release the lane-resident state so the lane holds
        // nothing of this failed attempt (ignore the result — the lane
        // itself may be the thing that failed).
        let _ = compute.drop_state(sref);
        return Err(e);
    }

    // Phase exit: move the trained state back out (export consumes the
    // lane-side state — no copy) for the coordinator's bit-identity check
    // / checkpoint / next-phase handoff.
    let (params, momenta) = compute
        .export_state(sref)
        .with_context(|| format!("rank {rank}: exporting state from lane {lane}"))?;
    state.params = params;
    state.momenta = momenta;

    if let Some(e) = eval_err {
        return Err(e);
    }

    Ok(WorkerOutput {
        rank,
        state,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_round_trip() {
        let ts = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            HostTensor::f32(vec![3], vec![5.0, 6.0, 7.0]),
        ];
        let mut flat = Vec::new();
        let offs = flatten_into(&ts, &mut flat).unwrap();
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(offs, vec![0, 4, 7]);
        let mut back = Vec::new();
        unflatten_from(&flat, &ts, &mut back).unwrap();
        assert_eq!(back, ts);
    }

    /// `unflatten_from` must reuse the output tensors' storage across
    /// calls (the step-loop steady state) instead of allocating fresh
    /// `Vec`s — observable as a stable data pointer.
    #[test]
    fn unflatten_reuses_existing_storage() {
        let ts = vec![
            HostTensor::f32(vec![2], vec![1.0, 2.0]),
            HostTensor::f32(vec![1], vec![3.0]),
        ];
        let mut out = Vec::new();
        unflatten_from(&[4.0, 5.0, 6.0], &ts, &mut out).unwrap();
        let p0 = out[0].as_f32().unwrap().as_ptr();
        unflatten_from(&[7.0, 8.0, 9.0], &ts, &mut out).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[7.0, 8.0]);
        assert_eq!(out[1].as_f32().unwrap(), &[9.0]);
        assert_eq!(
            out[0].as_f32().unwrap().as_ptr(),
            p0,
            "second unflatten must reuse the existing storage"
        );
        // shape change falls back to a rebuild
        let ts2 = vec![HostTensor::f32(vec![3], vec![0.0; 3])];
        unflatten_from(&[1.0, 2.0, 3.0], &ts2, &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].as_f32().unwrap(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn flatten_rejects_i32() {
        let ts = vec![HostTensor::i32(vec![1], vec![3])];
        let mut flat = Vec::new();
        assert!(flatten_into(&ts, &mut flat).is_err());
    }

    #[test]
    fn epoch_accounting() {
        let ctx_dataset = 1000usize;
        // free function behaviour via a minimal ctx is covered in trainer
        // integration tests; here just the arithmetic:
        let samples = 2500u64;
        assert_eq!(samples as f64 / ctx_dataset as f64, 2.5);
    }

    /// The phase-handoff stream position must be exact, not truncated to
    /// an epoch boundary: 32 steps × 8/worker × 4 workers = 1024 samples
    /// over a 1000-sample set is 256 per rank — 1.024 "epochs", which the
    /// old `epoch_at(..) as u32` seek collapsed to epoch 1, sample 0.
    #[test]
    fn phase_stream_start_is_exact_mid_epoch() {
        // no resume: position is simply samples_before / workers
        assert_eq!(phase_stream_start(1024, 0, 16, 4), 256);
        // checkpoint resume: skip_steps of *this* phase were folded into
        // samples_before by the planner; the stream start backs them out
        // (they are replayed batch-by-batch afterwards).
        assert_eq!(phase_stream_start(1024 + 3 * 64, 3, 16, 4), 256);
        // phase aligned on an epoch boundary stays aligned
        assert_eq!(phase_stream_start(2048, 0, 8, 4), 512);
    }

    /// End-to-end continuation: a second-phase loader seeded by
    /// `phase_stream_start` + `seek_samples` produces exactly the batches
    /// an uninterrupted single-phase loader would produce next.
    #[test]
    fn cross_phase_stream_matches_single_phase_run() {
        use crate::data::{Augment, Batch, Loader, SynthDataset};
        let workers = 4usize;
        let per_worker = 8usize;
        let phase1_steps = 32usize; // 1024 samples on a 1000-sample set
        let samples_before = (phase1_steps * per_worker * workers) as u64;
        for rank in 0..workers {
            let make = || {
                Loader::new(
                    SynthDataset::new(7, 10, 16, 3, 1000, 256),
                    Augment::standard(7),
                    rank,
                    workers,
                )
            };
            // single-phase: consume phase 1 then keep going
            let mut single = make();
            let mut b = Batch::empty();
            for _ in 0..phase1_steps {
                single.next_batch(per_worker, &mut b);
            }
            // two-phase: fresh loader, seek as run_phase does for phase 2
            let mut second = make();
            second.seek_samples(phase_stream_start(samples_before, 0, 16, workers));
            let mut b1 = Batch::empty();
            let mut b2 = Batch::empty();
            for _ in 0..4 {
                single.next_batch(16, &mut b1);
                second.next_batch(16, &mut b2);
                assert_eq!(b1.labels, b2.labels, "rank {rank} stream diverged");
                assert_eq!(b1.images, b2.images, "rank {rank} stream diverged");
            }
        }
    }
}
