//! Worker rank: one simulated GPU's training loop.
//!
//! Per step (the paper's data-parallel structure, §2):
//!   1. load the next local batch (shard of the synthetic set),
//!   2. `grad_step` executable → loss, local grads, local BN stats,
//!   3. all-reduce grads via the configured collective, **FP16 wire**,
//!   4. all-reduce BN stats, **FP32 wire** (paper §3.2 precision split),
//!      with the scalar step loss riding in this buffer (1 extra element)
//!      so the reported `loss_mean` is never quantised by the FP16
//!      gradient wire,
//!   5. scale by 1/N, `apply_step` executable (LARS) with the schedule's
//!      (lr, momentum) for this step's epoch.
//!
//! Parameters stay replicated: identical reduced grads + identical update
//! = identical weights on every rank (asserted in integration tests).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::collectives::{Collective, Endpoint, Wire};
use crate::data::{Batch, Loader};
use crate::runtime::{ArchManifest, ComputeClient, HostTensor};
use crate::sched::LrSchedule;
use crate::util::timer::Stopwatch;

use super::metrics::{Metrics, StepMetric};

/// Static per-phase context shared by all workers.
pub struct PhaseCtx {
    pub arch: ArchManifest,
    pub collective: Arc<dyn Collective>,
    pub grad_wire: Wire,
    pub lr: LrSchedule,
    pub label_smoothing: f32,
    pub weight_decay: f32,
    pub per_worker_batch: usize,
    pub workers: usize,
    pub steps: usize,
    /// Global step index of this phase's first step.
    pub first_step: usize,
    /// Samples consumed before this phase (for epoch accounting).
    pub samples_before: u64,
    /// Steps of this phase already consumed by an earlier (checkpointed)
    /// run — the loader fast-forwards past their batches on entry.
    pub skip_steps: usize,
    pub dataset_size: usize,
}

impl PhaseCtx {
    /// Epoch (continuous) after `samples` total processed samples.
    pub fn epoch_at(&self, samples: u64) -> f64 {
        samples as f64 / self.dataset_size as f64
    }

    pub fn grad_key(&self) -> String {
        format!(
            "{}/grad_b{}_ls{}",
            self.arch.name,
            self.per_worker_batch,
            (self.label_smoothing * 100.0).round() as i64
        )
    }

    pub fn apply_key(&self) -> String {
        format!("{}/apply", self.arch.name)
    }
}

/// Mutable per-rank state threaded through a phase.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub params: Vec<HostTensor>,
    pub momenta: Vec<HostTensor>,
    /// Running mean of the synchronized BN stats (rank 0 uses it for eval).
    pub bn_running: Vec<HostTensor>,
    pub bn_steps: u64,
}

/// Result of one rank finishing a phase.
pub struct WorkerOutput {
    pub rank: usize,
    pub state: WorkerState,
    /// Step metrics (only rank 0 fills this).
    pub metrics: Metrics,
}

/// Flatten f32 tensors into `flat` (resizing as needed); returns offsets.
pub fn flatten_into(tensors: &[HostTensor], flat: &mut Vec<f32>) -> Result<Vec<usize>> {
    let mut offsets = Vec::with_capacity(tensors.len() + 1);
    let total: usize = tensors.iter().map(|t| t.elems()).sum();
    flat.clear();
    flat.reserve(total);
    offsets.push(0);
    for t in tensors {
        flat.extend_from_slice(t.as_f32()?);
        offsets.push(flat.len());
    }
    Ok(offsets)
}

/// Scatter `flat` back into tensors shaped like `templates`.
pub fn unflatten_from(
    flat: &[f32],
    templates: &[HostTensor],
    out: &mut Vec<HostTensor>,
) -> Result<()> {
    out.clear();
    let mut off = 0;
    for t in templates {
        let n = t.elems();
        out.push(HostTensor::f32(
            t.shape().to_vec(),
            flat[off..off + n].to_vec(),
        ));
        off += n;
    }
    Ok(())
}

/// Run one phase on one rank. `ep` is this rank's mesh endpoint.
#[allow(clippy::too_many_arguments)]
pub fn run_phase(
    ctx: &PhaseCtx,
    rank: usize,
    ep: &mut Endpoint,
    compute: &ComputeClient,
    loader: &mut Loader,
    mut state: WorkerState,
) -> Result<WorkerOutput> {
    let grad_key = ctx.grad_key();
    let apply_key = ctx.apply_key();
    let n_params = ctx.arch.n_params();
    let n_bn = ctx.arch.n_bn();
    let inv_n = 1.0f32 / ctx.workers as f32;
    let mut metrics = Metrics::default();
    let mut batch = Batch::empty();
    let mut grad_flat: Vec<f32> = Vec::new();
    let mut bn_flat: Vec<f32> = Vec::new();
    let mut tag: u64 = 0;

    let img_shape = vec![
        ctx.per_worker_batch,
        ctx.arch.image_size,
        ctx.arch.image_size,
        ctx.arch.image_channels,
    ];

    // Start this phase's data stream at the schedule's current epoch
    // (not epoch 0 — a later phase continues the dataset pass), then, on
    // checkpoint resume, replay past the already-trained steps so the
    // sample stream continues exactly where the saved run stopped.
    loader.seek_epoch(ctx.epoch_at(ctx.samples_before -
        (ctx.skip_steps * ctx.per_worker_batch * ctx.workers) as u64) as u32);
    for _ in 0..ctx.skip_steps {
        loader.skip_batch(ctx.per_worker_batch);
    }

    for local_step in 0..ctx.steps {
        let mut sw = Stopwatch::new();
        let global_step = ctx.first_step + local_step;
        let samples = ctx.samples_before
            + (local_step as u64) * (ctx.per_worker_batch * ctx.workers) as u64;
        let epoch = ctx.epoch_at(samples);
        let total_batch = ctx.per_worker_batch * ctx.workers;
        let lr = ctx.lr.lr(epoch) as f32;
        let momentum = ctx.lr.momentum(epoch, total_batch) as f32;

        // 1. data
        let data_epoch = loader.next_batch(ctx.per_worker_batch, &mut batch);
        let t_data = sw.lap("data");

        // 2. local gradients
        let mut inputs = state.params.clone();
        inputs.push(HostTensor::f32(img_shape.clone(), batch.images.clone()));
        inputs.push(HostTensor::i32(
            vec![ctx.per_worker_batch],
            batch.labels.clone(),
        ));
        let out = compute
            .run(&grad_key, inputs)
            .with_context(|| format!("rank {rank} step {global_step}: grad_step"))?;
        let t_compute = sw.lap("compute");

        // 3. gradient all-reduce (FP16 wire)
        let loss_local = out[0].scalar()?;
        let grads = &out[1..1 + n_params];
        let bn_stats = &out[1 + n_params..1 + n_params + n_bn];
        flatten_into(grads, &mut grad_flat)?;
        ctx.collective
            .all_reduce(ep, &mut grad_flat, ctx.grad_wire, tag)?;
        tag += ctx.collective.tag_span(ctx.workers);
        for g in grad_flat.iter_mut() {
            *g *= inv_n;
        }

        // 4. BN-stat all-reduce (FP32 wire, paper §3.2). The scalar step
        // loss rides in this buffer — NOT in the gradient buffer — so the
        // reported loss is a pure-FP32 reduction even on the FP16 wire.
        flatten_into(bn_stats, &mut bn_flat)?;
        bn_flat.push(loss_local);
        ctx.collective.all_reduce(ep, &mut bn_flat, Wire::F32, tag)?;
        tag += ctx.collective.tag_span(ctx.workers);
        let loss_mean = f64::from(bn_flat.pop().unwrap()) / ctx.workers as f64;
        for s in bn_flat.iter_mut() {
            *s *= inv_n;
        }
        // Synced-stat aggregate for the eval path. The paper's "BN without
        // moving average" uses *current* statistics; for evaluation we keep
        // a recent-weighted EMA of the cross-worker synced stats (early-
        // training stats are stale — activations rescale as params move, so
        // a uniform mean underestimates late-run variance and detonates the
        // eval forward pass).
        {
            let alpha: f32 = if state.bn_steps == 0 { 1.0 } else { 0.2 };
            let mut off = 0;
            for t in state.bn_running.iter_mut() {
                let dst = t.as_f32_mut()?;
                for d in dst.iter_mut() {
                    *d += alpha * (bn_flat[off] - *d);
                    off += 1;
                }
            }
            state.bn_steps += 1;
        }
        let t_comm = sw.lap("comm");

        // 5. LARS update (the backend's apply entry point)
        let mut grads_avg = Vec::with_capacity(n_params);
        unflatten_from(&grad_flat, grads, &mut grads_avg)?;
        let mut ap_in =
            Vec::with_capacity(2 * n_params + n_params + 3);
        ap_in.extend(state.params.iter().cloned());
        ap_in.extend(state.momenta.iter().cloned());
        ap_in.extend(grads_avg);
        ap_in.push(HostTensor::scalar_f32(lr));
        ap_in.push(HostTensor::scalar_f32(momentum));
        ap_in.push(HostTensor::scalar_f32(ctx.weight_decay));
        let applied = compute
            .run(&apply_key, ap_in)
            .with_context(|| format!("rank {rank} step {global_step}: apply_step"))?;
        let (new_params, new_momenta) = applied.split_at(n_params);
        state.params = new_params.to_vec();
        state.momenta = new_momenta.to_vec();
        let t_apply = sw.lap("apply");

        if rank == 0 {
            metrics.push(StepMetric {
                step: global_step,
                epoch: data_epoch,
                loss: loss_mean,
                lr: lr as f64,
                momentum: momentum as f64,
                global_batch: total_batch,
                t_compute,
                t_comm,
                t_apply,
                t_data,
            });
        }
    }

    Ok(WorkerOutput {
        rank,
        state,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_round_trip() {
        let ts = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            HostTensor::f32(vec![3], vec![5.0, 6.0, 7.0]),
        ];
        let mut flat = Vec::new();
        let offs = flatten_into(&ts, &mut flat).unwrap();
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(offs, vec![0, 4, 7]);
        let mut back = Vec::new();
        unflatten_from(&flat, &ts, &mut back).unwrap();
        assert_eq!(back, ts);
    }

    #[test]
    fn flatten_rejects_i32() {
        let ts = vec![HostTensor::i32(vec![1], vec![3])];
        let mut flat = Vec::new();
        assert!(flatten_into(&ts, &mut flat).is_err());
    }

    #[test]
    fn epoch_accounting() {
        let ctx_dataset = 1000usize;
        // free function behaviour via a minimal ctx is covered in trainer
        // integration tests; here just the arithmetic:
        let samples = 2500u64;
        assert_eq!(samples as f64 / ctx_dataset as f64, 2.5);
    }
}
