//! Background snapshotter + durable-dir resume.
//!
//! The step loop never writes a snapshot itself: at a phase boundary the
//! coordinator *offers* the boundary state (or, in process mode, the
//! already-encoded checkpoint bytes rank 0 shipped) and moves on. A
//! dedicated thread encodes, pushes the object through the
//! [`StorageBackend`] with the PR-6 backoff retry loop, appends the
//! `snapshot` record to the run journal, and garbage-collects old
//! snapshots down to `keep_last` — in that order, so the journal never
//! names a snapshot that is not durably in the store, and GC never runs
//! ahead of the journal.
//!
//! Resume ([`latest_valid_snapshot`]) walks the `snap-*` objects newest
//! first and returns the first one whose checksum verifies — a snapshot
//! torn or corrupted mid-write costs one generation of progress, never
//! the run.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::storage::{put_with_retry, snapshot_backoff, StorageBackend};

use super::checkpoint::{self, CheckpointMeta};
use super::journal::{Journal, Record};
use super::worker::WorkerState;

/// Key prefix of snapshot objects; the zero-padded step makes
/// lexicographic order == step order.
const SNAP_PREFIX: &str = "snap-";

/// Object key of the snapshot at `step`.
pub fn snapshot_key(step: u64) -> String {
    format!("{SNAP_PREFIX}{step:08}.ckpt")
}

/// Counters the background thread maintains; surfaced in
/// `TrainReport` and `/status`. All time is spent *off* the step path.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SnapshotStats {
    /// Snapshots durably written.
    pub written: usize,
    /// Snapshots that failed even after the retry budget (the run
    /// continues; the next boundary tries again).
    pub failed: usize,
    /// Wall seconds the background thread spent encoding + writing.
    pub write_secs: f64,
    /// Step of the newest durable snapshot.
    pub last_step: Option<u64>,
}

enum Job {
    /// Boundary state to encode and store (in-process mode).
    State(Box<WorkerState>, CheckpointMeta),
    /// Pre-encoded checkpoint bytes (process mode reuses rank 0's
    /// boundary blob — already the exact on-disk format).
    Bytes(Vec<u8>, CheckpointMeta),
}

/// Handle to the background snapshot thread.
pub struct Snapshotter {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<SnapshotStats>>,
    every_steps: usize,
    keep_last: usize,
    /// Step of the last snapshot *offered* (not necessarily durable yet) —
    /// the cadence gate runs on the offering side.
    last_offered: Option<u64>,
}

impl Snapshotter {
    /// Spawn the background writer. `journal` (when present) receives a
    /// `snapshot` record after each durable write.
    pub fn start(
        backend: Box<dyn StorageBackend>,
        journal: Option<Arc<Mutex<Journal>>>,
        every_steps: usize,
        keep_last: usize,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let stats = Arc::new(Mutex::new(SnapshotStats::default()));
        let stats_bg = stats.clone();
        let keep = keep_last.max(1);
        let handle = std::thread::Builder::new()
            .name("snapshotter".to_string())
            .spawn(move || {
                let backoff = snapshot_backoff();
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    let (bytes, meta) = match job {
                        Job::Bytes(b, m) => (Ok(b), m),
                        Job::State(st, m) => (checkpoint::encode(&st, m), m),
                    };
                    let outcome = bytes.and_then(|bytes| {
                        let key = snapshot_key(meta.step);
                        put_with_retry(&*backend, &key, &bytes, &backoff)?;
                        if let Some(j) = &journal {
                            j.lock().unwrap().append(&Record::Snapshot {
                                step: meta.step,
                                samples: meta.samples,
                                key: key.clone(),
                            })?;
                        }
                        gc_old_snapshots(&*backend, keep)?;
                        Ok(())
                    });
                    let mut s = stats_bg.lock().unwrap();
                    s.write_secs += t0.elapsed().as_secs_f64();
                    match outcome {
                        Ok(()) => {
                            s.written += 1;
                            s.last_step = Some(meta.step);
                        }
                        Err(e) => {
                            s.failed += 1;
                            eprintln!("snapshot at step {} failed: {e:#}", meta.step);
                        }
                    }
                }
            })
            .expect("spawning the snapshotter thread");
        Self {
            tx: Some(tx),
            handle: Some(handle),
            stats,
            every_steps,
            keep_last: keep,
            last_offered: None,
        }
    }

    /// Cadence gate: the first boundary always snapshots; after that a
    /// boundary snapshots when ≥ `every_steps` steps have passed since
    /// the last offered one (`every_steps = 0` → every boundary).
    fn due(&self, step: u64) -> bool {
        match self.last_offered {
            None => true,
            Some(last) => step > last && (step - last) as usize >= self.every_steps,
        }
    }

    /// Offer boundary state (in-process mode). Clones the state only when
    /// a snapshot is actually due. Returns whether a job was enqueued.
    pub fn offer_state(&mut self, state: &WorkerState, meta: CheckpointMeta) -> bool {
        if !self.due(meta.step) {
            return false;
        }
        self.last_offered = Some(meta.step);
        if let Some(tx) = &self.tx {
            let _ = tx.send(Job::State(Box::new(state.clone()), meta));
        }
        true
    }

    /// Offer pre-encoded checkpoint bytes (process mode). The caller
    /// clones the blob only after `due` says yes, via the closure.
    pub fn offer_bytes(
        &mut self,
        meta: CheckpointMeta,
        bytes: impl FnOnce() -> Vec<u8>,
    ) -> bool {
        if !self.due(meta.step) {
            return false;
        }
        self.last_offered = Some(meta.step);
        if let Some(tx) = &self.tx {
            let _ = tx.send(Job::Bytes(bytes(), meta));
        }
        true
    }

    /// Current counters (the background thread updates them as it goes).
    pub fn stats(&self) -> SnapshotStats {
        *self.stats.lock().unwrap()
    }

    /// The configured retention depth.
    pub fn keep_last(&self) -> usize {
        self.keep_last
    }

    /// Close the queue and wait for in-flight snapshots to land; returns
    /// the final counters. Called once, after the run's final checkpoint
    /// logic — never from the step path.
    pub fn finish(mut self) -> SnapshotStats {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        *self.stats.lock().unwrap()
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// All snapshot keys in the store, sorted ascending by step.
pub fn list_snapshots(backend: &dyn StorageBackend) -> Result<Vec<String>> {
    let mut keys = backend.list(SNAP_PREFIX)?;
    keys.sort();
    Ok(keys)
}

/// Delete snapshots beyond the newest `keep`.
fn gc_old_snapshots(backend: &dyn StorageBackend, keep: usize) -> Result<()> {
    let keys = list_snapshots(backend)?;
    if keys.len() > keep {
        for key in &keys[..keys.len() - keep] {
            backend.delete(key)?;
        }
    }
    Ok(())
}

/// Newest snapshot that decodes and checksums cleanly, or `None` when no
/// valid snapshot exists. A corrupt or torn newer file is *skipped with a
/// warning* — falling back to the previous generation is the whole point
/// of keeping more than one.
pub fn latest_valid_snapshot(
    backend: &dyn StorageBackend,
) -> Result<Option<(WorkerState, CheckpointMeta, String)>> {
    let keys = list_snapshots(backend)?;
    for key in keys.iter().rev() {
        let bytes = match backend.get(key) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("snapshot {key} unreadable ({e:#}); falling back");
                continue;
            }
        };
        match checkpoint::decode(&bytes) {
            Ok((state, meta)) => return Ok(Some((state, meta, key.clone()))),
            Err(e) => {
                eprintln!("snapshot {key} invalid ({e:#}); falling back to the previous one");
            }
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;
    use crate::storage::LocalDir;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "flashsgd-snapshot-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn state(x: f32) -> WorkerState {
        WorkerState {
            params: vec![HostTensor::f32(vec![2], vec![x, x + 1.0])],
            momenta: vec![HostTensor::f32(vec![2], vec![0.0, 0.0])],
            bn_running: vec![],
            bn_steps: 0,
        }
    }

    fn store(dir: &std::path::Path) -> Box<dyn StorageBackend> {
        Box::new(LocalDir::create(dir).unwrap())
    }

    #[test]
    fn writes_snapshots_and_keeps_last() {
        let dir = scratch("gc");
        let mut s = Snapshotter::start(store(&dir), None, 0, 2);
        for step in [4u64, 8, 12] {
            let enq = s.offer_state(&state(step as f32), CheckpointMeta { step, samples: step * 16 });
            assert!(enq);
        }
        let stats = s.finish();
        assert_eq!(stats.written, 3);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.last_step, Some(12));
        assert!(stats.write_secs >= 0.0);

        let backend = store(&dir);
        assert_eq!(
            list_snapshots(&*backend).unwrap(),
            vec![snapshot_key(8), snapshot_key(12)],
            "keep_last = 2 must GC the oldest"
        );
        let (st, meta, key) = latest_valid_snapshot(&*backend).unwrap().unwrap();
        assert_eq!(meta, CheckpointMeta { step: 12, samples: 192 });
        assert_eq!(key, snapshot_key(12));
        assert_eq!(st.params, state(12.0).params);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cadence_gates_on_every_steps() {
        let dir = scratch("cadence");
        let mut s = Snapshotter::start(store(&dir), None, 8, 4);
        assert!(s.offer_state(&state(0.0), CheckpointMeta { step: 4, samples: 0 }));
        // Only 4 steps since the last snapshot: not due yet.
        assert!(!s.offer_state(&state(1.0), CheckpointMeta { step: 8, samples: 0 }));
        assert!(s.offer_state(&state(2.0), CheckpointMeta { step: 12, samples: 0 }));
        let stats = s.finish();
        assert_eq!(stats.written, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_snapshot_falls_back_to_previous() {
        let dir = scratch("fallback");
        let mut s = Snapshotter::start(store(&dir), None, 0, 4);
        s.offer_state(&state(1.0), CheckpointMeta { step: 4, samples: 64 });
        s.offer_state(&state(2.0), CheckpointMeta { step: 8, samples: 128 });
        s.finish();

        // Truncate the newest file mid-write (the crash signature).
        let newest = dir.join(snapshot_key(8));
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();

        let backend = store(&dir);
        let (st, meta, key) = latest_valid_snapshot(&*backend).unwrap().unwrap();
        assert_eq!(key, snapshot_key(4), "must fall back past the corrupt newest");
        assert_eq!(meta, CheckpointMeta { step: 4, samples: 64 });
        assert_eq!(st.params, state(1.0).params);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_valid_snapshot_is_none_not_error() {
        let dir = scratch("none");
        let backend = store(&dir);
        assert!(latest_valid_snapshot(&*backend).unwrap().is_none());
        backend.put(&snapshot_key(4), b"garbage").unwrap();
        assert!(latest_valid_snapshot(&*backend).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_records_each_durable_snapshot() {
        let dir = scratch("journal");
        let (journal, _) = Journal::open(&dir).unwrap();
        let journal = Arc::new(Mutex::new(journal));
        let mut s = Snapshotter::start(store(&dir), Some(journal.clone()), 0, 4);
        s.offer_state(&state(1.0), CheckpointMeta { step: 4, samples: 64 });
        s.finish();

        let replay = Journal::replay_dir(&dir).unwrap();
        assert_eq!(
            replay.records,
            vec![Record::Snapshot {
                step: 4,
                samples: 64,
                key: snapshot_key(4),
            }]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
