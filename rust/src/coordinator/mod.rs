//! The training coordinator (Layer 3 leader).
//!
//! [`Trainer`] owns a run end-to-end: it resolves a manifest and a compute
//! backend (the pure-Rust [`crate::runtime::ReferenceBackend`] by default;
//! PJRT over AOT artifacts with `--features pjrt`), starts a **multi-lane
//! compute pool** (one lane — thread + backend instance — per rank, so
//! ranks compute concurrently; `compute_lanes` in the config overrides the
//! width), materialises the initial parameters (the `init` entry point —
//! same He init as the paper's [10]), then executes the batch-size
//! schedule phase by phase. Each phase spawns one thread per simulated GPU
//! over a fresh mesh on the configured transport ([`Mesh`] in memory by
//! default, loopback [`TcpMesh`] with `transport.mode = "tcp"`; the
//! `coordinator`/`worker` subcommands in [`remote`] stretch the same
//! phases across processes); every rank pins its `(params, momenta)` into its
//! compute lane for the phase, so steady-state steps ship only batches,
//! reduced gradients and scalars. Within a step, gradient synchronization
//! is **overlapped with backprop** (paper §2.2): the lane streams
//! gradients in reverse layer order and the worker all-reduces
//! tensor-aligned buckets while later layers are still being computed
//! (`TrainConfig::bucket_bytes`; 0 = the serial schedule, bit-identical). Phase boundaries are where batch-size
//! control swaps every worker's `grad_step` executable (and, like the
//! paper's Exp. 2–4, may change the worker count); they are also the only
//! points where state is exported from the lanes — for the replication
//! invariant the coordinator *enforces* (all ranks bit-identical in
//! parameters, momenta and BN statistics), for checkpointing, and for the
//! next phase's import.
//!
//! Evaluation runs on rank 0's parameters with the *synchronized running
//! BN statistics* — the "Batch Normalization without Moving Average"
//! evaluation path (paper §3.2) — every `eval_every` global steps (a step
//! interval; rank 0 evaluates in-phase through its resident state), plus
//! once at the end of the run.

pub mod checkpoint;
pub mod journal;
pub mod metrics;
pub mod remote;
pub mod snapshot;
pub mod worker;

pub use checkpoint::CheckpointMeta;
pub use metrics::{EvalMetric, Metrics, StepMetric, Summary};
pub use snapshot::SnapshotStats;
pub use worker::NonFiniteError;

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::collectives::{
    self, presumed_wedged, ChaosConfig, ChaosTransport, Collective, Health, Mesh, MeshError,
    TcpMesh, TcpOptions, Transport, Wire,
};
use crate::config::{StragglerPolicy, TrainConfig, TransportConfig};
use crate::data::{Augment, Loader, SynthDataset};
use crate::runtime::{
    ArchManifest, BackendSpec, ComputeClient, ComputeService, HostTensor, Manifest,
};
use crate::storage::{self, LocalDir};
use crate::util::timer::Stopwatch;

use journal::{Journal, Record};
use snapshot::Snapshotter;
use worker::{PhaseCtx, WorkerOutput, WorkerState};

/// Result of a full training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub config_name: String,
    pub metrics: Metrics,
    pub summary: Summary,
    pub final_eval: Option<EvalMetric>,
    pub wall_secs: f64,
    /// Width of the compute pool this run used.
    pub lanes: usize,
    /// Highest number of compute requests observed executing at the same
    /// instant across lanes (≥ 2 means ranks genuinely overlapped).
    pub max_lane_concurrency: usize,
    /// Elastic-recovery events: each records a phase attempt that lost
    /// ranks and was re-planned on the survivors. Empty on a fault-free
    /// run.
    pub recoveries: Vec<RecoveryEvent>,
    /// Worker-rejoin events: each records a restarted worker re-admitted
    /// at a phase boundary, with the collective re-planned back *up*
    /// (process mode only — an in-process rank thread cannot restart).
    pub rejoins: Vec<RejoinEvent>,
    /// Straggler-demotion events (`[fault.straggler]` with `policy =
    /// demote | evict`): each records a chronically slow rank drained at a
    /// phase boundary through the elastic re-plan — never a mid-collective
    /// abort, never a charge against `fault.max_restarts`. Empty under
    /// `policy = observe` or on a homogeneous run.
    pub demotions: Vec<DemotionEvent>,
    /// Background-snapshot counters (`[checkpoint]`): how many snapshots
    /// landed and how long the *background* thread spent writing them.
    /// That time is reported here precisely because it is NOT part of any
    /// step's latency — snapshots are written off the step path.
    pub snapshots: SnapshotStats,
}

/// One elastic-recovery event: a rank death aborted a phase attempt and
/// the remaining steps were re-planned on the survivors.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Global step index of the afflicted phase's first step (the replay
    /// resumes from this phase-boundary state).
    pub phase_first_step: usize,
    /// Ranks declared dead in the failed attempt (indices local to that
    /// attempt's mesh).
    pub dead_ranks: Vec<usize>,
    /// Worker count of the failed attempt.
    pub workers_before: usize,
    /// Worker count the phase was re-planned to (global batch preserved).
    pub workers_after: usize,
    /// Per-worker batch after re-planning (`global_batch / workers_after`).
    pub per_worker_after: usize,
}

/// One straggler demotion: a rank whose local-work EWMA stayed above
/// `slow_factor ×` the cluster median for `grace_ms` was drained at a
/// phase boundary (policy `demote`), or removed outright (policy `evict`).
#[derive(Debug, Clone)]
pub struct DemotionEvent {
    /// Global step index of the boundary at which the straggler was
    /// drained (the first step run without it, unless readmitted).
    pub phase_first_step: usize,
    /// Mesh rank of the straggler in the afflicted phase.
    pub rank: usize,
    /// The straggler's local-work EWMA at confirmation, milliseconds.
    pub step_ms_ewma: f64,
    /// The live-cluster median EWMA it was judged against, milliseconds.
    pub median_ms: f64,
    /// Permanently removed (policy `evict`): no rejoin window is held.
    pub evicted: bool,
    /// Immediately readmitted at the same boundary (policy `demote` with
    /// `fault.rejoin_grace_ms` > 0): telemetry resets, the width never
    /// changes, and the run stays byte-identical to an undisturbed one.
    pub readmitted: bool,
}

/// One worker-rejoin event: a restarted worker process re-registered over
/// the control socket and was admitted at a phase boundary, growing the
/// collective back toward the planned width (the constant-global-batch
/// re-plan machinery run in reverse).
#[derive(Debug, Clone)]
pub struct RejoinEvent {
    /// Global step index of the first step run at the restored width.
    pub phase_first_step: usize,
    /// Control-plane id of the worker that rejoined.
    pub worker: usize,
    /// Worker count of the preceding (degraded) attempt.
    pub workers_before: usize,
    /// Worker count after re-admission.
    pub workers_after: usize,
    /// Per-worker batch after re-admission (`global_batch / workers_after`).
    pub per_worker_after: usize,
}

impl TrainReport {
    pub fn format(&self) -> String {
        let eval = match &self.final_eval {
            Some(e) => format!(
                "val loss {:.3}, top-1 acc {:.1}%",
                e.val_loss,
                e.accuracy * 100.0
            ),
            None => "no eval".to_string(),
        };
        let snaps = if self.snapshots.written + self.snapshots.failed > 0 {
            format!(
                "\n  snapshots: {} written, {} failed ({:.2}s off the step path{})",
                self.snapshots.written,
                self.snapshots.failed,
                self.snapshots.write_secs,
                match self.snapshots.last_step {
                    Some(s) => format!(", newest at step {s}"),
                    None => String::new(),
                }
            )
        } else {
            String::new()
        };
        format!(
            "[{}] {}\n  final: {}  (wall {:.1}s){}",
            self.config_name,
            self.summary.format(),
            eval,
            self.wall_secs,
            snaps
        )
    }
}

/// One planned phase (resolved from the batch schedule).
#[derive(Debug, Clone)]
struct PhasePlan {
    per_worker: usize,
    workers: usize,
    steps: usize,
    first_step: usize,
    samples_before: u64,
    /// Steps of this phase consumed before a checkpoint resume.
    skipped: usize,
}

/// The run coordinator.
pub struct Trainer {
    config: TrainConfig,
    manifest: Manifest,
    backend: BackendSpec,
    save_to: Option<std::path::PathBuf>,
    resume_from: Option<std::path::PathBuf>,
}

impl Trainer {
    /// Train on the pure-Rust [`crate::runtime::ReferenceBackend`] with its
    /// built-in synthesized manifest — the default: no Python, no artifact
    /// files, no XLA.
    pub fn new(config: TrainConfig) -> Result<Self> {
        let manifest = crate::runtime::builtin_manifest();
        manifest.arch(&config.arch)?; // fail fast on unknown arch
        Ok(Self {
            config,
            manifest,
            backend: BackendSpec::Reference,
            save_to: None,
            resume_from: None,
        })
    }

    /// Train on the PJRT backend over AOT artifacts in `artifacts_dir`
    /// (requires building with `--features pjrt` and the real `xla` crate).
    #[cfg(feature = "pjrt")]
    pub fn with_pjrt(
        config: TrainConfig,
        artifacts_dir: impl AsRef<std::path::Path>,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.arch(&config.arch)?; // fail fast on unknown arch
        Ok(Self {
            config,
            manifest,
            backend: BackendSpec::Pjrt,
            save_to: None,
            resume_from: None,
        })
    }

    /// Save the final training state to `path` when the run completes.
    pub fn with_checkpoint(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.save_to = Some(path.into());
        self
    }

    /// Resume from a checkpoint written by [`Self::with_checkpoint`]: state
    /// is restored and the schedule continues at the saved step with the
    /// identical sample stream.
    pub fn with_resume(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.resume_from = Some(path.into());
        self
    }

    pub fn config(&self) -> &TrainConfig {
        &self.config
    }

    /// Resolve the batch schedule into concrete phases with step counts.
    fn plan_phases(&self) -> Vec<PhasePlan> {
        let cfg = &self.config;
        let sched = &cfg.batch;
        let mut plans: Vec<PhasePlan> = Vec::new();
        let mut first_step = 0usize;
        let mut samples = 0u64;
        let mut total_steps = 0usize;
        for e in 0..sched.total_epochs {
            let ph = sched.at(e);
            let steps_in_epoch = cfg.train_size.div_ceil(ph.total_batch());
            let mut remaining = steps_in_epoch;
            if cfg.max_steps > 0 {
                if total_steps >= cfg.max_steps {
                    break;
                }
                remaining = remaining.min(cfg.max_steps - total_steps);
            }
            if remaining == 0 {
                break;
            }
            let extend = plans
                .last()
                .map(|p| p.per_worker == ph.per_worker && p.workers == ph.workers)
                .unwrap_or(false);
            if extend {
                plans.last_mut().unwrap().steps += remaining;
            } else {
                plans.push(PhasePlan {
                    per_worker: ph.per_worker,
                    workers: ph.workers,
                    steps: remaining,
                    first_step,
                    samples_before: samples,
                    skipped: 0,
                });
            }
            total_steps += remaining;
            first_step += remaining;
            samples += (remaining * ph.total_batch()) as u64;
        }
        plans
    }

    /// Names of the executables this run needs.
    fn preload_names(&self, plans: &[PhasePlan]) -> Result<Vec<String>> {
        let arch = self.manifest.arch(&self.config.arch)?;
        let mut names = vec!["init".to_string(), "apply".to_string()];
        names.push(arch.eval_exec()?.name.clone());
        for p in plans {
            let g = arch.grad_exec(p.per_worker, self.config.label_smoothing)?;
            if !names.contains(&g.name) {
                names.push(g.name.clone());
            }
        }
        Ok(names)
    }

    /// Run the configured training job.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.config;
        let arch = self.manifest.arch(&cfg.arch)?.clone();
        let mut plans = self.plan_phases();
        if plans.is_empty() {
            bail!("schedule produced zero steps");
        }

        // Checkpoint resume: restore state, drop the already-trained prefix
        // of the plan (partially-consumed phases record `skipped` so the
        // workers can replay their loaders to the exact sample position).
        // `--resume` takes either a checkpoint file or a durable directory
        // (journal + snapshots); the directory form verifies the journal's
        // config hash and falls back past corrupt snapshots.
        let cfg_hash = run_config_hash(cfg);
        let resuming_dir = self.resume_from.as_ref().is_some_and(|p| p.is_dir());
        let resumed: Option<(WorkerState, checkpoint::CheckpointMeta)> = self
            .resume_from
            .as_ref()
            .map(|p| load_resume(p, cfg_hash))
            .transpose()?
            .flatten();
        if let Some((st, meta)) = &resumed {
            apply_resume(&mut plans, &arch, st, meta)?;
        }

        // Durability: open the run journal + background snapshotter when
        // `[checkpoint] dir` is set. The RunStart record (fsynced before
        // any training happens) stamps the config hash every later resume
        // is verified against.
        let durable = open_durability(cfg, cfg_hash, resuming_dir)?;
        let journal = durable.as_ref().map(|d| d.journal.clone());
        let mut snapshotter = durable.map(|d| d.snapshotter);

        let preload = self.preload_names(&plans)?;
        let preload_refs: Vec<&str> = preload.iter().map(|s| s.as_str()).collect();
        // One compute lane per rank (the widest phase wins) so every rank's
        // grad/apply executes concurrently; `compute_lanes` pins the width
        // explicitly (1 = the old fully-serialized configuration).
        let lanes = if cfg.compute_lanes > 0 {
            cfg.compute_lanes
        } else {
            plans.iter().map(|p| p.workers).max().unwrap_or(1)
        };
        let svc = ComputeService::start_pool(
            self.backend,
            self.manifest.clone(),
            &cfg.arch,
            &preload_refs,
            lanes,
        )
        .context("starting compute pool")?;
        let client = svc.client();
        let mut sw = Stopwatch::new();

        // Initial state: from the checkpoint, or the init artifact
        // (deterministic He init, paper init per [10]).
        let mut state = match resumed {
            Some((st, _)) => st,
            None => {
                let params = client.run(
                    &format!("{}/init", cfg.arch),
                    vec![HostTensor::i32(vec![1], vec![cfg.seed as i32])],
                )?;
                let momenta: Vec<HostTensor> = params
                    .iter()
                    .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
                    .collect();
                let bn_running: Vec<HostTensor> = arch
                    .bn_layers
                    .iter()
                    .map(|b| HostTensor::f32(vec![2, b.width], vec![0.0; 2 * b.width]))
                    .collect();
                WorkerState {
                    params,
                    momenta,
                    bn_running,
                    bn_steps: 0,
                }
            }
        };

        let dataset = SynthDataset::new(
            cfg.seed,
            arch.num_classes,
            arch.image_size,
            arch.image_channels,
            cfg.train_size,
            (cfg.train_size / 4).max(arch.num_classes),
        );

        let mut all_metrics = Metrics::default();
        let wire = if cfg.grad_wire == "fp16" { Wire::F16 } else { Wire::F32 };
        // Elastic-recovery bookkeeping: ranks lost so far shrink every
        // later phase's worker count (a dead machine stays dead), and the
        // total restart budget is shared across the run.
        let mut lost = 0usize;
        let mut restarts_used = 0usize;
        let mut recoveries: Vec<RecoveryEvent> = Vec::new();
        let mut demotions: Vec<DemotionEvent> = Vec::new();
        for (phase_idx, plan) in plans.iter().enumerate() {
            let global_batch = plan.per_worker * plan.workers;
            let mut attempt = 0usize;
            loop {
                let workers = effective_workers(&arch, plan.workers, lost, global_batch, cfg)?;
                let per_worker = global_batch / workers;
                let degraded = workers != plan.workers;
                if degraded {
                    // The degraded per-worker batch was not in the preload
                    // set; load its grad executable into every lane now.
                    let g = arch.grad_exec(per_worker, cfg.label_smoothing)?;
                    client
                        .load(&cfg.arch, &[g.name.as_str()])
                        .context("loading grad executable for the re-planned batch")?;
                }
                // A fixed-shape collective spec that no longer fits the
                // survivor count falls back to the auto torus/ring rule.
                let collective: Arc<dyn Collective> =
                    Arc::from(collectives::by_name_elastic(&cfg.collective, workers, degraded)?);
                let ctx = Arc::new(PhaseCtx {
                    arch: arch.clone(),
                    collective,
                    grad_wire: wire,
                    lr: cfg.lr.clone(),
                    label_smoothing: cfg.label_smoothing,
                    weight_decay: cfg.weight_decay,
                    per_worker_batch: per_worker,
                    workers,
                    steps: plan.steps,
                    first_step: plan.first_step,
                    samples_before: plan.samples_before,
                    skip_steps: plan.skipped,
                    dataset_size: cfg.train_size,
                    eval_every: cfg.eval_every,
                    eval_batches: cfg.eval_batches,
                    bucket_bytes: cfg.bucket_bytes,
                    attempt,
                    fault: cfg.fault.clone(),
                });

                // Write-ahead: the phase start is durable before any step
                // of it runs.
                if let Some(j) = &journal {
                    j.lock().unwrap().append(&Record::PhaseStart {
                        phase: phase_idx,
                        attempt: attempt as u32,
                        step: plan.first_step as u64,
                        samples: plan.samples_before,
                        workers,
                    })?;
                }

                match run_phase_on_mesh(&ctx, &cfg.transport, &client, &dataset, cfg.seed, &state) {
                    PhaseOutcome::Complete { mut outputs, stragglers } => {
                        // Parameters are replicated: identical reduced
                        // gradients plus an identical update must leave
                        // every rank with bit-identical state. Enforce the
                        // invariant (on the survivors, after a recovery)
                        // before carrying rank 0 forward.
                        outputs.sort_by_key(|o| o.rank);
                        for o in &outputs[1..] {
                            if !tensors_bit_identical(&o.state.params, &outputs[0].state.params)
                                || !tensors_bit_identical(
                                    &o.state.momenta,
                                    &outputs[0].state.momenta,
                                )
                                || !tensors_bit_identical(
                                    &o.state.bn_running,
                                    &outputs[0].state.bn_running,
                                )
                            {
                                bail!(
                                    "replicated-parameter invariant violated: rank {} \
                                     diverged from rank 0 after step {}",
                                    o.rank,
                                    plan.first_step + plan.steps
                                );
                            }
                        }
                        let o = outputs.swap_remove(0);
                        all_metrics.merge(o.metrics);
                        state = o.state;
                        // Boundary snapshot: hand the state to the
                        // background writer and move on — the next phase
                        // starts immediately, never waiting on disk.
                        if let Some(s) = &mut snapshotter {
                            s.offer_state(
                                &state,
                                checkpoint::CheckpointMeta {
                                    step: (plan.first_step + plan.steps) as u64,
                                    samples: plan.samples_before
                                        + (plan.steps * plan.per_worker * plan.workers) as u64,
                                },
                            );
                        }
                        // Straggler demotion happens here — at the phase
                        // boundary, after the phase completed cleanly — so
                        // the mitigation never aborts a collective and never
                        // charges the restart budget. Under `demote` with a
                        // rejoin grace the rank is readmitted on the spot
                        // (the event is the record; the world keeps its
                        // width, so the numerics are untouched). Without
                        // grace, or under `evict`, the rank leaves the world
                        // through the same elastic re-plan a death uses.
                        if cfg.fault.enabled
                            && cfg.fault.straggler.policy != StragglerPolicy::Observe
                        {
                            for s in &stragglers {
                                let evicted =
                                    cfg.fault.straggler.policy == StragglerPolicy::Evict;
                                let readmitted =
                                    !evicted && !cfg.fault.rejoin_grace.is_zero();
                                if !readmitted {
                                    lost += 1;
                                }
                                demotions.push(DemotionEvent {
                                    phase_first_step: plan.first_step + plan.steps,
                                    rank: s.rank,
                                    step_ms_ewma: s.step_ms_ewma,
                                    median_ms: s.median_ms,
                                    evicted,
                                    readmitted,
                                });
                            }
                        }
                        break;
                    }
                    PhaseOutcome::Failed { dead, err } => {
                        let err = err.context(format!(
                            "phase at step {} failed (attempt {attempt}, {workers} workers, \
                             dead ranks {dead:?})",
                            plan.first_step
                        ));
                        if worker::error_is_non_finite(&err) {
                            // The numeric health guard is deterministic: a
                            // replay from the same boundary state reproduces
                            // the same NaN/Inf. Fail now instead of burning
                            // the restart budget on guaranteed repeats.
                            return Err(err.context(
                                "numeric health guard tripped (deterministic — not retried)",
                            ));
                        }
                        if !cfg.fault.enabled {
                            return Err(err);
                        }
                        if dead.is_empty() {
                            // Nothing was detected dead — this is not a
                            // rank death, so a retry would just repeat it.
                            return Err(err);
                        }
                        if restarts_used >= cfg.fault.max_restarts {
                            return Err(err.context(format!(
                                "fault.max_restarts ({}) exhausted",
                                cfg.fault.max_restarts
                            )));
                        }
                        lost += dead.len();
                        restarts_used += 1;
                        let new_workers =
                            effective_workers(&arch, plan.workers, lost, global_batch, cfg)
                                .map_err(|e| e.context(err))?;
                        // Write-ahead: the recovery is durable before the
                        // re-plan it describes is adopted.
                        if let Some(j) = &journal {
                            j.lock().unwrap().append(&Record::Recovery {
                                phase: phase_idx,
                                dead: dead.clone(),
                            })?;
                        }
                        recoveries.push(RecoveryEvent {
                            phase_first_step: plan.first_step,
                            dead_ranks: dead,
                            workers_before: workers,
                            workers_after: new_workers,
                            per_worker_after: global_batch / new_workers,
                        });
                        // `state` still holds the phase-boundary state (the
                        // workers train on clones): the retry replays the
                        // whole phase from its start on the survivors, with
                        // the global batch — and therefore the step count
                        // and LR/momentum schedule — unchanged.
                        attempt += 1;
                    }
                }
            }
        }

        // Final evaluation at the completed-step count. In-phase interval
        // evals (rank 0, every `eval_every` steps) already landed in the
        // metrics; if the last one coincides with the end of the run, reuse
        // it instead of double-pushing a duplicate step.
        let total_steps = all_metrics.steps.last().map(|s| s.step + 1).unwrap_or(0);
        let final_eval = match all_metrics.evals.last() {
            Some(e) if e.step == total_steps => Some(e.clone()),
            _ => {
                let e = self
                    .evaluate(&client, &arch, &dataset, &state, total_steps)
                    .ok();
                if let Some(e) = &e {
                    all_metrics.push_eval(e.clone());
                }
                e
            }
        };

        // Final-state checkpoint.
        if let Some(path) = &self.save_to {
            let last = plans.last().unwrap();
            let meta = checkpoint::CheckpointMeta {
                step: (last.first_step + last.steps) as u64,
                samples: last.samples_before
                    + (last.steps * last.per_worker * last.workers) as u64,
            };
            checkpoint::save(path, &state, meta)
                .with_context(|| format!("saving checkpoint to {path:?}"))?;
        }

        // Seal the durable run: drain the background snapshotter (bounded —
        // only queued writes), then append RunEnd so it is the journal's
        // final record and a later resume can see the run completed.
        let snapshots = snapshotter.take().map(Snapshotter::finish).unwrap_or_default();
        if let Some(j) = &journal {
            let last = plans.last().unwrap();
            j.lock().unwrap().append(&Record::RunEnd {
                step: (last.first_step + last.steps) as u64,
                samples: last.samples_before
                    + (last.steps * last.per_worker * last.workers) as u64,
            })?;
        }

        let summary = all_metrics.summary();
        Ok(TrainReport {
            config_name: cfg.name.clone(),
            metrics: all_metrics,
            summary,
            final_eval,
            wall_secs: sw.lap("total"),
            lanes,
            max_lane_concurrency: svc.stats().max_concurrent(),
            recoveries,
            rejoins: Vec::new(),
            demotions,
            snapshots,
        })
    }

    /// Top-1 validation accuracy + loss on `eval_batches` validation
    /// batches, using the synchronized running BN statistics. Shares the
    /// batch loop and normalisation with rank 0's in-phase interval evals
    /// ([`worker::eval_over_val_split`]); only the execution path differs —
    /// here a stateless `run` with the coordinator-held parameters.
    fn evaluate(
        &self,
        client: &ComputeClient,
        arch: &crate::runtime::ArchManifest,
        dataset: &SynthDataset,
        state: &WorkerState,
        step: usize,
    ) -> Result<EvalMetric> {
        let loader = Loader::new(dataset.clone(), Augment::none(), 0, 1);
        worker::eval_over_val_split(
            arch,
            &loader,
            self.config.eval_batches,
            step,
            |exec, images, labels| {
                let key = format!("{}/{exec}", arch.name);
                let mut inputs = state.params.clone();
                inputs.extend(state.bn_running.iter().cloned());
                inputs.push(images);
                inputs.push(labels);
                client.run(&key, inputs)
            },
        )
    }
}

/// Bitwise equality of two f32 tensor lists. Compares the raw bits rather
/// than `==`, so a run whose ranks all hold identically-NaN state reports
/// as a NaN loss downstream instead of a phantom "rank diverged" error.
fn tensors_bit_identical(a: &[HostTensor], b: &[HostTensor]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.shape() == y.shape()
                && match (x.as_f32(), y.as_f32()) {
                    (Ok(xs), Ok(ys)) => {
                        xs.len() == ys.len()
                            && xs.iter().zip(ys).all(|(p, q)| p.to_bits() == q.to_bits())
                    }
                    _ => x == y,
                }
        })
}

/// Largest worker count the survivors support for this phase: at most
/// `planned - lost`, must divide the global batch (preserving it exactly —
/// and with it the step count and LR/momentum schedule), and the manifest
/// must have a grad executable for the resulting per-worker batch.
fn effective_workers(
    arch: &ArchManifest,
    planned: usize,
    lost: usize,
    global_batch: usize,
    cfg: &TrainConfig,
) -> Result<usize> {
    let cap = planned.saturating_sub(lost);
    if cap == 0 {
        bail!("no survivors left: {lost} of {planned} planned ranks are dead");
    }
    for s in (1..=cap).rev() {
        if global_batch % s == 0 && arch.grad_exec(global_batch / s, cfg.label_smoothing).is_ok() {
            return Ok(s);
        }
    }
    bail!(
        "cannot re-plan a {global_batch}-sample global batch onto {cap} survivors: \
         no divisor of the batch has a grad executable in the manifest"
    )
}

/// The hash a durable run stamps into its journal's `RunStart` record and
/// every `--resume <dir>` is verified against. Both run modes hash the
/// same thing — the resolved [`TrainConfig`]'s `Debug` rendering — so the
/// in-process [`Trainer`] and the `coordinator` subcommand agree on what
/// "same config" means without either needing the original TOML text.
pub(crate) fn run_config_hash(cfg: &TrainConfig) -> u64 {
    journal::config_hash(&format!("{cfg:?}"))
}

/// Load resume state from `path`: a checkpoint *file* (the original
/// `--resume run.ckpt` form) loads directly; a durable *directory*
/// (journal + snapshots) verifies the journal's config hash, then picks
/// the newest snapshot whose checksum holds, falling back past corrupt or
/// torn ones. A durable directory whose journal proves the run started
/// but holds no usable snapshot resumes as a fresh run (`Ok(None)`) — no
/// progress was durable, so step 0 is the truth.
pub(crate) fn load_resume(
    path: &std::path::Path,
    cfg_hash: u64,
) -> Result<Option<(WorkerState, CheckpointMeta)>> {
    if !path.is_dir() {
        let loaded = checkpoint::load(path)
            .with_context(|| format!("loading checkpoint from {}", path.display()))?;
        return Ok(Some(loaded));
    }
    let replay = Journal::replay_dir(path)?;
    if replay.records.is_empty() {
        bail!(
            "--resume {}: no run journal found — is this a durable run directory \
             (one a run with [checkpoint] dir wrote)?",
            path.display()
        );
    }
    verify_run_start(&replay.records, cfg_hash, path)?;
    let backend = LocalDir::create(path)?;
    match snapshot::latest_valid_snapshot(&backend)? {
        Some((state, meta, key)) => {
            eprintln!(
                "[resume] restored snapshot '{key}' (step {}, {} samples) from {}",
                meta.step,
                meta.samples,
                path.display()
            );
            Ok(Some((state, meta)))
        }
        None => {
            eprintln!(
                "[resume] journal found but no usable snapshot in {} — \
                 replaying the run from step 0",
                path.display()
            );
            Ok(None)
        }
    }
}

/// Restore a resume position into `plans`: verify the state fits `arch`,
/// drop the already-trained prefix of the schedule (a partially-consumed
/// phase records `skipped`, which the workers replay their loaders
/// through via `seek_samples` to the exact sample position), and
/// cross-check the recomputed sample position against the checkpoint's
/// own accounting — `meta.step` under a *different* batch schedule lands
/// at a different sample count, and silently resuming there would desync
/// the data stream from the saved run. Shared by both run modes.
pub(crate) fn apply_resume(
    plans: &mut Vec<PhasePlan>,
    arch: &ArchManifest,
    st: &WorkerState,
    meta: &CheckpointMeta,
) -> Result<()> {
    if st.params.len() != arch.n_params() {
        bail!(
            "checkpoint has {} params, arch {} has {} — wrong model?",
            st.params.len(),
            arch.name,
            arch.n_params()
        );
    }
    let mut skip = meta.step as usize;
    plans.retain_mut(|p| {
        if skip == 0 {
            true
        } else if skip >= p.steps {
            skip -= p.steps;
            false
        } else {
            let batch = (p.per_worker * p.workers) as u64;
            p.skipped = skip;
            p.steps -= skip;
            p.first_step += skip;
            p.samples_before += skip as u64 * batch;
            skip = 0;
            true
        }
    });
    if plans.is_empty() {
        bail!(
            "checkpoint step {} is already at/past the end of this schedule",
            meta.step
        );
    }
    let resumed_samples = plans[0].samples_before;
    if resumed_samples != meta.samples {
        bail!(
            "checkpoint mismatch: checkpoint says step {} = {} samples, but \
             this schedule reaches step {} after {} samples — was the \
             checkpoint taken under a different batch schedule?",
            meta.step,
            meta.samples,
            meta.step,
            resumed_samples
        );
    }
    Ok(())
}

/// The durable-run plumbing: the write-ahead journal (shared with the
/// background snapshotter, which appends `snapshot` records into it) and
/// the snapshotter itself.
pub(crate) struct Durability {
    pub(crate) journal: Arc<Mutex<Journal>>,
    pub(crate) snapshotter: Snapshotter,
}

/// Open (or continue) the durable-run machinery when `[checkpoint] dir`
/// is set; `None` otherwise. A fresh run refuses a directory that already
/// holds a journal — continuing one is what `--resume` is for — and a
/// resume verifies the existing journal's config hash. Either way a new
/// `RunStart` record is appended and fsynced before any training runs.
pub(crate) fn open_durability(
    cfg: &TrainConfig,
    cfg_hash: u64,
    resuming: bool,
) -> Result<Option<Durability>> {
    if !cfg.checkpoint.enabled() {
        return Ok(None);
    }
    let dir = storage::local_path(&cfg.checkpoint.dir).to_path_buf();
    let (mut journal, records) = Journal::open(&dir)?;
    if !records.is_empty() {
        if !resuming {
            bail!(
                "{} already contains a run journal; pass --resume {} to continue \
                 that run, or point [checkpoint] dir at a fresh directory",
                dir.display(),
                dir.display()
            );
        }
        verify_run_start(&records, cfg_hash, &dir)?;
    }
    journal.append(&Record::RunStart {
        config_hash: cfg_hash,
        name: cfg.name.clone(),
    })?;
    let backend = storage::open_backend(&cfg.checkpoint.dir)?;
    let journal = Arc::new(Mutex::new(journal));
    let snapshotter = Snapshotter::start(
        backend,
        Some(journal.clone()),
        cfg.checkpoint.every_steps,
        cfg.checkpoint.keep_last,
    );
    Ok(Some(Durability { journal, snapshotter }))
}

/// Check a replayed journal's `RunStart` against this run's config hash.
fn verify_run_start(
    records: &[Record],
    cfg_hash: u64,
    dir: &std::path::Path,
) -> Result<()> {
    let recorded = records.iter().find_map(|r| match r {
        Record::RunStart { config_hash, .. } => Some(*config_hash),
        _ => None,
    });
    match recorded {
        Some(h) if h != cfg_hash => bail!(
            "config hash mismatch: the journal in {} was written under config \
             {h:016x}, this run resolves to {cfg_hash:016x} — resuming under a \
             different config would silently change the schedule",
            dir.display()
        ),
        Some(_) => Ok(()),
        None => bail!(
            "journal in {} has records but no run_start — corrupt or foreign file",
            dir.display()
        ),
    }
}

/// One confirmed straggler observation: the monitor saw `rank`'s
/// local-work EWMA above threshold vs the live median for the configured
/// grace. Carried out of the phase so mitigation can act at the boundary.
#[derive(Debug, Clone, Copy)]
struct StragglerReading {
    rank: usize,
    step_ms_ewma: f64,
    median_ms: f64,
}

/// Lower median of a non-empty sample (deterministic, outlier-robust: with
/// one straggler among n the straggler's own EWMA never drags the
/// reference point it is judged against).
fn median_ms(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    v[(v.len() - 1) / 2]
}

/// Outcome of one phase attempt across the mesh.
enum PhaseOutcome {
    /// Every rank finished; outputs carry the exported states, and
    /// `stragglers` any chronically slow ranks the monitor confirmed.
    Complete {
        outputs: Vec<WorkerOutput>,
        stragglers: Vec<StragglerReading>,
    },
    /// At least one rank errored or panicked. `dead` lists the ranks the
    /// health layer declared dead (genuine casualties — not the victims
    /// that merely unwound with a [`MeshError`] because a peer died);
    /// `err` is the most informative error observed.
    Failed {
        dead: Vec<usize>,
        err: anyhow::Error,
    },
}

/// Build one endpoint per rank on the configured transport: `"memory"` is
/// the in-process mesh (the default — bit-identical to the behaviour
/// before the transport layer existed), `"tcp"` runs the same ranks over
/// loopback sockets, exercising the frame codec and reader threads under
/// the full training loop. Either way the phase logic above sees only
/// `dyn Transport`. With `[fault.chaos]` enabled every endpoint is
/// wrapped in a [`ChaosTransport`] injecting the seeded fault schedule;
/// disabled (the default) the endpoints are returned unwrapped, so the
/// hot path carries no chaos branches at all.
fn build_endpoints(
    transport: &TransportConfig,
    chaos: &ChaosConfig,
    n: usize,
) -> Result<Vec<Box<dyn Transport>>> {
    fn boxed<T: Transport + 'static>(
        eps: Vec<T>,
        chaos: &ChaosConfig,
    ) -> Vec<Box<dyn Transport>> {
        if chaos.enabled {
            let (wrapped, _counters) = ChaosTransport::wrap_all(eps, chaos);
            wrapped
                .into_iter()
                .map(|ep| Box::new(ep) as Box<dyn Transport>)
                .collect()
        } else {
            eps.into_iter()
                .map(|ep| Box::new(ep) as Box<dyn Transport>)
                .collect()
        }
    }
    match transport.mode.as_str() {
        "memory" => Ok(boxed(Mesh::new(n), chaos)),
        "tcp" => {
            let opts = TcpOptions {
                max_frame_bytes: transport.max_frame_bytes,
                backoff: transport.backoff.clone(),
                reconnect_attempts: transport.reconnect_attempts,
                resync_window: transport.resync_window,
                link_policy: None,
            };
            Ok(boxed(
                TcpMesh::loopback_opts(n, opts).context("building the loopback TCP mesh")?,
                chaos,
            ))
        }
        other => bail!("unknown transport.mode {other:?}"),
    }
}

/// Spawn `ctx.workers` rank threads over a fresh mesh (in-memory or
/// loopback TCP, per `transport`) and run the phase. Rank 0 starts from
/// `state`; every rank receives a clone (parameters are replicated in
/// data-parallel training), so the caller keeps the phase-boundary state
/// for a recovery replay.
///
/// Failure propagation: a rank that errors or panics is marked dead in the
/// mesh's shared [`Health`] table, which flips the abort flag — every
/// other rank's bounded-wait `recv` notices within a tick and unwinds with
/// a [`MeshError`], so the whole phase fails in bounded time instead of
/// deadlocking on the dead rank's silent channels. When fault tolerance is
/// enabled, a heartbeat monitor additionally declares ranks dead whose
/// heartbeat goes stale (hung, not crashed), and each `recv` carries a
/// `rank_timeout` deadline as a last line of defence.
fn run_phase_on_mesh(
    ctx: &Arc<PhaseCtx>,
    transport: &TransportConfig,
    client: &ComputeClient,
    dataset: &SynthDataset,
    seed: u64,
    state: &WorkerState,
) -> PhaseOutcome {
    let n = ctx.workers;
    let mesh = match build_endpoints(transport, &ctx.fault.chaos, n) {
        Ok(m) => m,
        Err(err) => {
            // No rank ever started: nothing is dead, nothing to recover —
            // this is an environment failure, not a rank death.
            return PhaseOutcome::Failed { dead: vec![], err };
        }
    };
    let health: Arc<Health> = mesh[0].health_arc();

    // Heartbeat monitor: flags ranks whose heartbeat goes stale (a hang —
    // e.g. stuck compute — never trips the channel-level detection). A
    // stale rank that is still *completing steps* at its own recorded pace
    // is slow, not wedged — `presumed_wedged` spares it (the satellite fix
    // for false-positive kills on long steps). The same scan doubles as
    // the straggler detector: a rank whose local-work EWMA stays above
    // `slow_factor ×` the live median for `straggler.grace` is confirmed
    // into `stragglers` for the boundary policy to act on.
    let monitor_stop = Arc::new(AtomicBool::new(false));
    let stragglers: Arc<Mutex<Vec<StragglerReading>>> = Arc::new(Mutex::new(Vec::new()));
    let monitor = if ctx.fault.enabled {
        let health = health.clone();
        let stop = monitor_stop.clone();
        let interval = ctx.fault.heartbeat_interval;
        let timeout_ms = ctx.fault.rank_timeout.as_millis() as u64;
        let scfg = ctx.fault.straggler;
        let confirmed = stragglers.clone();
        Some(std::thread::spawn(move || {
            let n = health.n_ranks();
            let mut slow_since: Vec<Option<std::time::Instant>> = vec![None; n];
            let mut flagged = vec![false; n];
            while !stop.load(Ordering::Acquire) {
                for r in 0..n {
                    if health.is_done(r) || health.is_dead(r) {
                        continue;
                    }
                    if presumed_wedged(
                        health.millis_since_beat(r),
                        timeout_ms,
                        health.millis_since_progress(r),
                        health.step_ewma_ms(r),
                    ) {
                        health.mark_dead(r);
                    }
                }
                // Straggler scan (telemetry is free; action is gated on the
                // policy at the phase boundary).
                let judged: Vec<f64> = (0..n)
                    .filter(|&r| !health.is_dead(r) && health.step_samples(r) >= scfg.min_samples)
                    .filter_map(|r| health.step_ewma_ms(r))
                    .collect();
                if judged.len() >= 2 {
                    let med = median_ms(judged);
                    for r in 0..n {
                        if flagged[r] || health.is_dead(r) || health.is_done(r) {
                            continue;
                        }
                        let over = med > 0.0
                            && health.step_samples(r) >= scfg.min_samples
                            && health
                                .step_ewma_ms(r)
                                .is_some_and(|e| e > scfg.slow_factor * med);
                        if !over {
                            slow_since[r] = None;
                            continue;
                        }
                        let since = *slow_since[r].get_or_insert_with(std::time::Instant::now);
                        if since.elapsed() >= scfg.grace {
                            flagged[r] = true;
                            confirmed.lock().unwrap().push(StragglerReading {
                                rank: r,
                                step_ms_ewma: health.step_ewma_ms(r).unwrap_or(0.0),
                                median_ms: med,
                            });
                        }
                    }
                }
                std::thread::sleep(interval);
            }
        }))
    } else {
        None
    };

    let mut handles = Vec::with_capacity(n);
    for (rank, mut ep) in mesh.into_iter().enumerate() {
        if ctx.fault.enabled {
            ep.set_recv_deadline(Some(ctx.fault.rank_timeout));
        }
        let ctx = ctx.clone();
        let client = client.clone();
        let dataset = dataset.clone();
        let health = health.clone();
        let st = WorkerState {
            params: state.params.clone(),
            momenta: state.momenta.clone(),
            bn_running: state.bn_running.clone(),
            bn_steps: state.bn_steps,
        };
        let handle = std::thread::Builder::new()
            .name(format!("rank{rank}"))
            .spawn(move || -> Result<WorkerOutput> {
                let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    let mut loader =
                        Loader::new(dataset, Augment::standard(seed), rank, ctx.workers);
                    worker::run_phase(&ctx, rank, &mut *ep, &client, &mut loader, st)
                }));
                let out = match result {
                    Ok(Ok(o)) => Ok(o),
                    Ok(Err(e)) => {
                        // A rank that unwound with a MeshError is a
                        // *victim* of someone else's death — marking it
                        // dead too would shrink the survivor set for
                        // nothing. Only genuine local failures count.
                        if e.downcast_ref::<MeshError>().is_none() {
                            health.mark_dead(rank);
                        }
                        Err(e)
                    }
                    Err(payload) => {
                        health.mark_dead(rank);
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".to_string());
                        Err(anyhow::anyhow!("rank {rank} panicked: {msg}"))
                    }
                };
                // Every exit — clean, victim, or casualty — marks the rank
                // done (= thread no longer running), so the monitor never
                // declares an already-exited rank dead for going silent.
                health.mark_done(rank);
                out
            })
            .map_err(|e| anyhow::anyhow!("spawning rank {rank}: {e}"));
        match handle {
            Ok(h) => handles.push(h),
            Err(e) => {
                // Could not even spawn the rank: abort whatever did start.
                health.mark_dead(rank);
                for h in handles {
                    let _ = h.join();
                }
                monitor_stop.store(true, Ordering::Release);
                if let Some(m) = monitor {
                    let _ = m.join();
                }
                return PhaseOutcome::Failed {
                    dead: health.dead_ranks(),
                    err: e,
                };
            }
        }
    }

    // Joins are bounded: any failure marks a rank dead, the abort flag
    // flips, and every blocked recv unwinds within a tick.
    let mut outputs = Vec::with_capacity(n);
    let mut casualty_err: Option<anyhow::Error> = None;
    let mut victim_err: Option<anyhow::Error> = None;
    for (rank, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(o)) => outputs.push(o),
            Ok(Err(e)) => {
                let e = e.context(format!("rank {rank} failed"));
                if e.downcast_ref::<MeshError>().is_some() {
                    victim_err.get_or_insert(e);
                } else {
                    casualty_err.get_or_insert(e);
                }
            }
            Err(_) => {
                // catch_unwind inside the thread converts panics to Err;
                // reaching here means the thread died outside it.
                health.mark_dead(rank);
                casualty_err
                    .get_or_insert_with(|| anyhow::anyhow!("rank {rank} thread died"));
            }
        }
    }
    monitor_stop.store(true, Ordering::Release);
    if let Some(m) = monitor {
        let _ = m.join();
    }

    match casualty_err.or(victim_err) {
        None => PhaseOutcome::Complete {
            outputs,
            stragglers: stragglers.lock().unwrap().clone(),
        },
        Some(err) => PhaseOutcome::Failed {
            dead: health.dead_ranks(),
            err,
        },
    }
}
