//! Training metrics: loss curve, throughput, step-time breakdown.
//!
//! Rank 0 records one [`StepMetric`] per optimizer step (loss is the
//! cross-worker mean — it rides along in the FP32 BN-statistic all-reduce
//! buffer, so it costs one extra element and is never quantised by the
//! FP16 gradient wire). `Metrics::summary()` feeds the run report and
//! EXPERIMENTS.md; `to_csv()` dumps the raw curve.

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats;

/// One optimizer step as seen by rank 0.
#[derive(Debug, Clone)]
pub struct StepMetric {
    pub step: usize,
    pub epoch: u32,
    pub loss: f64,
    pub lr: f64,
    pub momentum: f64,
    pub global_batch: usize,
    /// Seconds stalled waiting on the backward pass (compute the
    /// communication could not hide).
    pub t_compute: f64,
    /// Seconds of **exposed** communication: bucket reductions run after
    /// backprop had already delivered its last gradient, plus the BN-stat
    /// all-reduce. This is the part of comm that extends the step.
    pub t_comm: f64,
    /// Seconds of bucket reductions overlapped with the still-running
    /// backward pass (hidden comm — the pipeline's win; 0 on the
    /// single-bucket/serial schedule).
    pub t_comm_hidden: f64,
    /// Seconds in apply_step (optimizer).
    pub t_apply: f64,
    /// Seconds in data loading.
    pub t_data: f64,
}

impl StepMetric {
    pub fn total_secs(&self) -> f64 {
        self.t_compute + self.t_comm + self.t_comm_hidden + self.t_apply + self.t_data
    }

    /// Lossless JSON encoding of one step — the process mode ships rank 0's
    /// curve over the control socket with this, so every field round-trips
    /// (unlike [`Metrics::to_json`], which reports a digest).
    pub fn to_wire(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("step".into(), Json::Num(self.step as f64));
        m.insert("epoch".into(), Json::Num(self.epoch as f64));
        m.insert("loss".into(), Json::Num(self.loss));
        m.insert("lr".into(), Json::Num(self.lr));
        m.insert("momentum".into(), Json::Num(self.momentum));
        m.insert("global_batch".into(), Json::Num(self.global_batch as f64));
        m.insert("t_compute".into(), Json::Num(self.t_compute));
        m.insert("t_comm".into(), Json::Num(self.t_comm));
        m.insert("t_comm_hidden".into(), Json::Num(self.t_comm_hidden));
        m.insert("t_apply".into(), Json::Num(self.t_apply));
        m.insert("t_data".into(), Json::Num(self.t_data));
        Json::Obj(m)
    }

    pub fn from_wire(j: &Json) -> Result<Self> {
        Ok(Self {
            step: j.get("step")?.as_usize()?,
            epoch: j.get("epoch")?.as_usize()? as u32,
            loss: j.get("loss")?.as_f64()?,
            lr: j.get("lr")?.as_f64()?,
            momentum: j.get("momentum")?.as_f64()?,
            global_batch: j.get("global_batch")?.as_usize()?,
            t_compute: j.get("t_compute")?.as_f64()?,
            t_comm: j.get("t_comm")?.as_f64()?,
            t_comm_hidden: j.get("t_comm_hidden")?.as_f64()?,
            t_apply: j.get("t_apply")?.as_f64()?,
            t_data: j.get("t_data")?.as_f64()?,
        })
    }
}

/// One evaluation point.
#[derive(Debug, Clone)]
pub struct EvalMetric {
    pub step: usize,
    pub val_loss: f64,
    pub accuracy: f64,
}

impl EvalMetric {
    /// Lossless JSON encoding (see [`StepMetric::to_wire`]).
    pub fn to_wire(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("step".into(), Json::Num(self.step as f64));
        m.insert("val_loss".into(), Json::Num(self.val_loss));
        m.insert("accuracy".into(), Json::Num(self.accuracy));
        Json::Obj(m)
    }

    pub fn from_wire(j: &Json) -> Result<Self> {
        Ok(Self {
            step: j.get("step")?.as_usize()?,
            val_loss: j.get("val_loss")?.as_f64()?,
            accuracy: j.get("accuracy")?.as_f64()?,
        })
    }
}

/// Accumulated run metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub steps: Vec<StepMetric>,
    pub evals: Vec<EvalMetric>,
}

/// Aggregate summary of a run (or a phase).
#[derive(Debug, Clone)]
pub struct Summary {
    pub steps: usize,
    pub images: usize,
    pub wall_secs: f64,
    pub images_per_sec: f64,
    pub first_loss: f64,
    pub last_loss: f64,
    /// Mean per-step seconds in each bucket.
    pub mean_compute: f64,
    pub mean_comm: f64,
    /// Mean per-step seconds of comm hidden behind backprop (overlapped
    /// bucket reductions).
    pub mean_comm_hidden: f64,
    pub mean_apply: f64,
    pub mean_data: f64,
    /// **Exposed** communication share of the step (the paper's
    /// scaling-efficiency antagonist). Comm hidden behind the backward
    /// pass does not count — that is exactly what the bucketed pipeline
    /// buys.
    pub comm_fraction: f64,
}

impl Metrics {
    pub fn push(&mut self, m: StepMetric) {
        self.steps.push(m);
    }

    pub fn push_eval(&mut self, e: EvalMetric) {
        self.evals.push(e);
    }

    pub fn summary(&self) -> Summary {
        let n = self.steps.len();
        let images: usize = self.steps.iter().map(|s| s.global_batch).sum();
        let wall: f64 = self.steps.iter().map(|s| s.total_secs()).sum();
        let get = |f: fn(&StepMetric) -> f64| -> Vec<f64> { self.steps.iter().map(f).collect() };
        let comp = stats::mean(&get(|s| s.t_compute));
        let comm = stats::mean(&get(|s| s.t_comm));
        let hidden = stats::mean(&get(|s| s.t_comm_hidden));
        let apply = stats::mean(&get(|s| s.t_apply));
        let data = stats::mean(&get(|s| s.t_data));
        let total = comp + comm + hidden + apply + data;
        Summary {
            steps: n,
            images,
            wall_secs: wall,
            images_per_sec: if wall > 0.0 { images as f64 / wall } else { 0.0 },
            first_loss: self.steps.first().map_or(f64::NAN, |s| s.loss),
            last_loss: self.steps.last().map_or(f64::NAN, |s| s.loss),
            mean_compute: comp,
            mean_comm: comm,
            mean_comm_hidden: hidden,
            mean_apply: apply,
            mean_data: data,
            comm_fraction: if total > 0.0 { comm / total } else { 0.0 },
        }
    }

    /// Smoothed loss curve (EMA, alpha 0.1) sampled every `every` steps.
    pub fn loss_curve(&self, every: usize) -> Vec<(usize, f64)> {
        let losses: Vec<f64> = self.steps.iter().map(|s| s.loss).collect();
        let smooth = stats::ema(&losses, 0.1);
        self.steps
            .iter()
            .zip(smooth)
            .filter(|(s, _)| every <= 1 || s.step % every == 0)
            .map(|(s, l)| (s.step, l))
            .collect()
    }

    /// CSV dump: step curve with timing columns.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "step,epoch,loss,lr,momentum,global_batch,t_compute,t_comm,t_comm_hidden,t_apply,t_data\n",
        );
        for s in &self.steps {
            out.push_str(&format!(
                "{},{},{:.6},{:.6},{:.4},{},{:.6},{:.6},{:.6},{:.6},{:.6}\n",
                s.step,
                s.epoch,
                s.loss,
                s.lr,
                s.momentum,
                s.global_batch,
                s.t_compute,
                s.t_comm,
                s.t_comm_hidden,
                s.t_apply,
                s.t_data
            ));
        }
        out
    }

    pub fn merge(&mut self, other: Metrics) {
        self.steps.extend(other.steps);
        self.evals.extend(other.evals);
    }

    /// Lossless JSON encoding of the whole curve — the `done` message of
    /// the process mode carries this, so the coordinator's merged metrics
    /// are field-for-field what an in-process run would have recorded.
    pub fn to_wire(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "steps".into(),
            Json::Arr(self.steps.iter().map(|s| s.to_wire()).collect()),
        );
        m.insert(
            "evals".into(),
            Json::Arr(self.evals.iter().map(|e| e.to_wire()).collect()),
        );
        Json::Obj(m)
    }

    pub fn from_wire(j: &Json) -> Result<Self> {
        let steps = j
            .get("steps")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, s)| StepMetric::from_wire(s).with_context(|| format!("step record #{i}")))
            .collect::<Result<Vec<_>>>()?;
        let evals = j
            .get("evals")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, e)| EvalMetric::from_wire(e).with_context(|| format!("eval record #{i}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { steps, evals })
    }

    /// Structured run report (machine-readable twin of `Summary::format`).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let s = self.summary();
        let mut top = BTreeMap::new();
        let mut summary = BTreeMap::new();
        summary.insert("steps".into(), Json::Num(s.steps as f64));
        summary.insert("images".into(), Json::Num(s.images as f64));
        summary.insert("wall_secs".into(), Json::Num(s.wall_secs));
        summary.insert("images_per_sec".into(), Json::Num(s.images_per_sec));
        summary.insert("first_loss".into(), Json::Num(s.first_loss));
        summary.insert("last_loss".into(), Json::Num(s.last_loss));
        summary.insert("comm_fraction".into(), Json::Num(s.comm_fraction));
        summary.insert("mean_comm_hidden".into(), Json::Num(s.mean_comm_hidden));
        top.insert("summary".into(), Json::Obj(summary));
        top.insert(
            "loss_curve".into(),
            Json::Arr(
                self.loss_curve(1)
                    .into_iter()
                    .map(|(step, loss)| {
                        Json::Arr(vec![Json::Num(step as f64), Json::Num(loss)])
                    })
                    .collect(),
            ),
        );
        top.insert(
            "evals".into(),
            Json::Arr(
                self.evals
                    .iter()
                    .map(|e| {
                        let mut m = BTreeMap::new();
                        m.insert("step".into(), Json::Num(e.step as f64));
                        m.insert("val_loss".into(), Json::Num(e.val_loss));
                        m.insert("accuracy".into(), Json::Num(e.accuracy));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(top)
    }
}

impl Summary {
    pub fn format(&self) -> String {
        format!(
            "steps {}  imgs {}  {:.1} img/s  loss {:.3}→{:.3}  \
             step breakdown: compute {:.1}ms comm {:.1}ms (+{:.1}ms hidden) \
             apply {:.1}ms data {:.1}ms (exposed comm {:.1}%)",
            self.steps,
            self.images,
            self.images_per_sec,
            self.first_loss,
            self.last_loss,
            self.mean_compute * 1e3,
            self.mean_comm * 1e3,
            self.mean_comm_hidden * 1e3,
            self.mean_apply * 1e3,
            self.mean_data * 1e3,
            self.comm_fraction * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: usize, loss: f64) -> StepMetric {
        StepMetric {
            step: i,
            epoch: 0,
            loss,
            lr: 0.1,
            momentum: 0.9,
            global_batch: 32,
            t_compute: 0.010,
            t_comm: 0.005,
            t_comm_hidden: 0.0,
            t_apply: 0.002,
            t_data: 0.003,
        }
    }

    #[test]
    fn summary_aggregates() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.push(step(i, 2.0 - i as f64 * 0.1));
        }
        let s = m.summary();
        assert_eq!(s.steps, 10);
        assert_eq!(s.images, 320);
        assert!((s.wall_secs - 0.2).abs() < 1e-9);
        assert!((s.images_per_sec - 1600.0).abs() < 1.0);
        assert!((s.comm_fraction - 0.25).abs() < 1e-9);
        assert!(s.last_loss < s.first_loss);
        assert!(s.format().contains("img/s"));
    }

    #[test]
    fn hidden_comm_is_excluded_from_the_exposed_fraction() {
        let mut m = Metrics::default();
        let mut s = step(0, 1.0);
        s.t_comm_hidden = 0.005;
        m.push(s);
        let sum = m.summary();
        // total 10+5+5+2+3 = 25ms; only the 5ms exposed comm counts
        assert!((sum.comm_fraction - 0.2).abs() < 1e-9);
        assert!((sum.mean_comm_hidden - 0.005).abs() < 1e-12);
        assert!((m.steps[0].total_secs() - 0.025).abs() < 1e-12);
    }

    #[test]
    fn csv_and_curve() {
        let mut m = Metrics::default();
        for i in 0..6 {
            m.push(step(i, 1.0));
        }
        m.push_eval(EvalMetric {
            step: 5,
            val_loss: 0.9,
            accuracy: 0.5,
        });
        let csv = m.to_csv();
        assert_eq!(csv.lines().count(), 7);
        assert!(csv.starts_with("step,"));
        let curve = m.loss_curve(2);
        assert_eq!(curve.len(), 3); // steps 0, 2, 4
    }

    #[test]
    fn json_report_round_trips() {
        let mut m = Metrics::default();
        for i in 0..4 {
            m.push(step(i, 1.5));
        }
        m.push_eval(EvalMetric { step: 3, val_loss: 1.2, accuracy: 0.4 });
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("summary").unwrap().get("steps").unwrap().as_usize().unwrap(),
            4
        );
        assert_eq!(parsed.get("evals").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("loss_curve").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn wire_codec_round_trips_every_field() {
        let mut m = Metrics::default();
        for i in 0..3 {
            let mut s = step(i, 1.0 + i as f64 * 0.125);
            s.epoch = 2;
            s.t_comm_hidden = 0.001 * i as f64;
            m.push(s);
        }
        m.push_eval(EvalMetric { step: 2, val_loss: 0.875, accuracy: 0.3125 });
        // through text, as the control socket would carry it
        let text = m.to_wire().to_string();
        let back = Metrics::from_wire(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.steps.len(), 3);
        assert_eq!(back.evals.len(), 1);
        for (a, b) in m.steps.iter().zip(&back.steps) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.lr, b.lr);
            assert_eq!(a.momentum, b.momentum);
            assert_eq!(a.global_batch, b.global_batch);
            assert_eq!(a.t_compute, b.t_compute);
            assert_eq!(a.t_comm, b.t_comm);
            assert_eq!(a.t_comm_hidden, b.t_comm_hidden);
            assert_eq!(a.t_apply, b.t_apply);
            assert_eq!(a.t_data, b.t_data);
        }
        assert_eq!(m.evals[0].val_loss, back.evals[0].val_loss);
        assert_eq!(m.evals[0].accuracy, back.evals[0].accuracy);
        // malformed records fail loudly, not with defaults
        assert!(Metrics::from_wire(&Json::parse("{\"steps\":[{}],\"evals\":[]}").unwrap()).is_err());
    }

    #[test]
    fn empty_metrics_are_safe() {
        let s = Metrics::default().summary();
        assert_eq!(s.steps, 0);
        assert_eq!(s.images_per_sec, 0.0);
        assert!(s.first_loss.is_nan());
    }
}
