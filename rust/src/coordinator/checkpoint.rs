//! Training-state checkpointing: save/restore parameters, momenta and the
//! synchronized BN statistics, plus run-position metadata.
//!
//! Binary format (little-endian, versioned):
//!
//! ```text
//! magic "FSGD"  u32 version  u64 step  u64 samples  u64 bn_steps
//! u32 n_sections
//! per section: u32 n_tensors, per tensor: u32 rank, u32 dims.., f32 data..
//! sections: params, momenta, bn_running
//! trailing crc32-like checksum (fletcher-64 over all preceding bytes)
//! ```
//!
//! Tensors carry their shapes so a checkpoint is self-describing and a
//! mismatch against the manifest (e.g. wrong arch) fails loudly.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

use super::worker::WorkerState;

const MAGIC: &[u8; 4] = b"FSGD";
const VERSION: u32 = 1;

/// Run-position metadata stored alongside the tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointMeta {
    /// Next global optimizer step.
    pub step: u64,
    /// Total samples consumed.
    pub samples: u64,
}

/// Fletcher-64 checksum (simple, dependency-free integrity check). Also
/// the framing checksum of the write-ahead run journal
/// (`coordinator::journal`) and the config hash recorded in it.
pub(crate) fn fletcher64(bytes: &[u8]) -> u64 {
    let mut a: u64 = 0;
    let mut b: u64 = 0;
    for chunk in bytes.chunks(4) {
        let mut word = [0u8; 4];
        word[..chunk.len()].copy_from_slice(chunk);
        a = (a + u32::from_le_bytes(word) as u64) % 0xFFFF_FFFF;
        b = (b + a) % 0xFFFF_FFFF;
    }
    (b << 32) | a
}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn tensor(&mut self, t: &HostTensor) -> Result<()> {
        let data = t.as_f32()?;
        self.u32(t.shape().len() as u32);
        for &d in t.shape() {
            self.u32(d as u32);
        }
        for &x in data {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }
    fn section(&mut self, ts: &[HostTensor]) -> Result<()> {
        self.u32(ts.len() as u32);
        for t in ts {
            self.tensor(t)?;
        }
        Ok(())
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn tensor(&mut self) -> Result<HostTensor> {
        let rank = self.u32()? as usize;
        if rank > 8 {
            bail!("implausible tensor rank {rank} (corrupt checkpoint?)");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        let elems: usize = shape.iter().product();
        let raw = self.take(4 * elems)?;
        let mut data = Vec::with_capacity(elems);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(HostTensor::f32(shape, data))
    }
    fn section(&mut self) -> Result<Vec<HostTensor>> {
        let n = self.u32()? as usize;
        if n > 1_000_000 {
            bail!("implausible section size {n}");
        }
        (0..n).map(|_| self.tensor()).collect()
    }
}

/// Serialise `state` + `meta` into the checkpoint byte format (magic,
/// version, meta, three tensor sections, trailing fletcher-64). This is
/// also the wire encoding the process mode uses to ship phase-boundary
/// state between coordinator and workers — the same self-describing,
/// checksummed bytes whether they land on disk or on a socket.
pub fn encode(state: &WorkerState, meta: CheckpointMeta) -> Result<Vec<u8>> {
    let mut w = Writer { buf: Vec::new() };
    w.buf.extend_from_slice(MAGIC);
    w.u32(VERSION);
    w.u64(meta.step);
    w.u64(meta.samples);
    w.u64(state.bn_steps);
    w.u32(3);
    w.section(&state.params)?;
    w.section(&state.momenta)?;
    w.section(&state.bn_running)?;
    let sum = fletcher64(&w.buf);
    w.u64(sum);
    Ok(w.buf)
}

/// Inverse of [`encode`]; verifies magic, version and checksum.
pub fn decode(bytes: &[u8]) -> Result<(WorkerState, CheckpointMeta)> {
    if bytes.len() < 8 {
        bail!("checkpoint too small");
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    let got = fletcher64(body);
    if want != got {
        bail!("checkpoint checksum mismatch ({got:#x} != {want:#x}) — corrupt file");
    }

    let mut r = Reader { buf: body, pos: 0 };
    if r.take(4)? != MAGIC {
        bail!("not a flashsgd checkpoint (bad magic)");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("checkpoint version {version} unsupported (want {VERSION})");
    }
    let step = r.u64()?;
    let samples = r.u64()?;
    let bn_steps = r.u64()?;
    let n_sections = r.u32()?;
    if n_sections != 3 {
        bail!("expected 3 sections, found {n_sections}");
    }
    let params = r.section()?;
    let momenta = r.section()?;
    let bn_running = r.section()?;
    if r.pos != body.len() {
        bail!("trailing garbage in checkpoint");
    }
    if params.len() != momenta.len() {
        bail!(
            "param/momentum arity mismatch: {} vs {}",
            params.len(),
            momenta.len()
        );
    }
    Ok((
        WorkerState {
            params,
            momenta,
            bn_running,
            bn_steps,
        },
        CheckpointMeta { step, samples },
    ))
}

/// Serialise `state` + `meta` to `path` (atomic: write temp, rename).
pub fn save(path: impl AsRef<Path>, state: &WorkerState, meta: CheckpointMeta) -> Result<()> {
    let path = path.as_ref();
    let bytes = encode(state, meta)?;

    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))?;
    Ok(())
}

/// Load a checkpoint; verifies magic, version and checksum.
pub fn load(path: impl AsRef<Path>) -> Result<(WorkerState, CheckpointMeta)> {
    let path = path.as_ref();
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut bytes)?;
    decode(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> WorkerState {
        WorkerState {
            params: vec![
                HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                HostTensor::f32(vec![4], vec![0.1, 0.2, 0.3, 0.4]),
            ],
            momenta: vec![
                HostTensor::f32(vec![2, 3], vec![0.0; 6]),
                HostTensor::f32(vec![4], vec![0.5; 4]),
            ],
            bn_running: vec![HostTensor::f32(vec![2, 8], vec![0.25; 16])],
            bn_steps: 17,
        }
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("fsgd-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let meta = CheckpointMeta { step: 42, samples: 1337 };
        let s = state();
        save(&path, &s, meta).unwrap();
        let (loaded, m2) = load(&path).unwrap();
        assert_eq!(m2, meta);
        assert_eq!(loaded.params, s.params);
        assert_eq!(loaded.momenta, s.momenta);
        assert_eq!(loaded.bn_running, s.bn_running);
        assert_eq!(loaded.bn_steps, 17);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn encode_decode_round_trip_without_a_file() {
        // The process mode ships these bytes over a socket instead of
        // through the filesystem — the codec must stand on its own.
        let meta = CheckpointMeta { step: 7, samples: 99 };
        let s = state();
        let bytes = encode(&s, meta).unwrap();
        let (loaded, m2) = decode(&bytes).unwrap();
        assert_eq!(m2, meta);
        assert_eq!(loaded.params, s.params);
        assert_eq!(loaded.momenta, s.momenta);
        assert_eq!(loaded.bn_running, s.bn_running);
        assert_eq!(loaded.bn_steps, s.bn_steps);
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join(format!("fsgd-ckpt-c-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        save(&path, &state(), CheckpointMeta { step: 1, samples: 2 }).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let dir = std::env::temp_dir().join(format!("fsgd-ckpt-m-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"nope").unwrap();
        assert!(load(&path).is_err());
        // valid file truncated mid-tensor
        save(&path, &state(), CheckpointMeta { step: 0, samples: 0 }).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fletcher_is_stable_and_sensitive() {
        let a = fletcher64(b"hello world");
        assert_eq!(a, fletcher64(b"hello world"));
        assert_ne!(a, fletcher64(b"hello worle"));
        // order sensitivity: same words, different order (a plain sum of
        // 4-byte words would collide here; fletcher's b-term does not)
        assert_ne!(fletcher64(b"aaaabbbb"), fletcher64(b"bbbbaaaa"));
    }
}
