//! Process mode: the coordinator and its workers as **separate OS
//! processes**, joined over a TCP control socket.
//!
//! `flashsgd coordinator --config cfg.toml` binds `transport.bind`, waits
//! for the widest phase's worker count to register, then drives the same
//! phase schedule as the in-process [`Trainer`](super::Trainer) — except
//! each rank now lives in a `flashsgd worker --join addr` process. The
//! control plane speaks the length-prefixed [`frame`] codec used by the
//! data mesh: JSON control frames plus [`frame::KIND_BLOB`] frames
//! carrying phase-boundary state in the checkpoint byte format
//! ([`checkpoint::encode`] — the same self-describing, checksummed bytes
//! whether they land on disk or on a socket).
//!
//! Per phase attempt:
//!
//! 1. coordinator → each participant: `prepare` (rank, geometry, schedule
//!    position, `seq` tag) + a state blob;
//! 2. each worker binds a fresh data listener and answers `ready {addr}`;
//! 3. coordinator → all: `start {addrs}`; workers form the rank-to-rank
//!    data mesh with [`tcp::connect_mesh`] and run the phase, pumping
//!    `beat` frames so the coordinator can spot hung ranks;
//! 4. each worker reports `done` (+ state blob; rank 0 attaches the phase
//!    metrics) or `failed {victim}`.
//!
//! The coordinator enforces the replicated-parameter invariant by
//! comparing every rank's state blob byte-for-byte against rank 0's, then
//! decodes rank 0's as the next phase-boundary state. Elastic recovery
//! mirrors the in-process runner: a worker whose control socket drops,
//! whose heartbeat goes stale, or which reports a non-victim failure is
//! declared dead ("a dead machine stays dead"), survivors are told to
//! `abort` so their blocked collectives unwind, and the phase replays on a
//! re-planned survivor mesh with the global batch preserved. Stale frames
//! from an aborted attempt are fenced off by the per-attempt `seq` tag.
//!
//! **Rejoin** is recovery's other half: after registration closes, the
//! coordinator keeps accepting on the control socket, so a restarted
//! `flashsgd worker --join` re-registers like any first-time joiner and is
//! admitted at the next phase boundary under a fresh connection id. With
//! `fault.rejoin_grace > 0` a degraded boundary *waits* up to the grace
//! for the replacement before re-planning — the replay then runs at full
//! width, per-worker batch steps back up, and (because the attempt ships
//! phase-boundary state to every rank and byte-compares every returned
//! blob) the run's final checkpoint is byte-identical to an undisturbed
//! run's. Each admission is recorded as a [`RejoinEvent`].
//!
//! **Durability** (the crash/resume half of robustness): with
//! `[checkpoint] dir` set the coordinator keeps a write-ahead run journal
//! and hands rank 0's phase-boundary blobs to the background snapshotter
//! (they are already the exact checkpoint byte format — no re-encode).
//! `flashsgd coordinator --resume <dir>` replays the journal, restores
//! the newest valid snapshot, and re-enters the schedule at the saved
//! position via the same plan-trimming as the in-process trainer — so a
//! SIGKILL'd-and-resumed run's final checkpoint is byte-identical to an
//! undisturbed run's. Workers are **orphan-safe**: a worker whose control
//! link dies holds on for `[fault] coordinator_grace_ms`, re-dials, and
//! re-registers with the restarted coordinator through the join door
//! instead of exiting.
//!
//! With `transport.http` set, a plain-HTTP endpoint serves `GET /status`
//! (run state, including per-rank heartbeat ages, reconnect counts, the
//! newest durable snapshot step, and the journal position) and
//! `GET /metrics` (the merged metrics report) as JSON.

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::path::Path;
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::collectives::transport::{frame, tcp};
use crate::collectives::{
    self, presumed_wedged, BackoffConfig, ChaosCounters, ChaosTransport, Collective, Counters,
    Health, MeshError, Transport, Wire,
};
use crate::config::{StragglerPolicy, TrainConfig};
use crate::data::{Augment, Loader, SynthDataset};
use crate::runtime::{ArchManifest, BackendSpec, ComputeClient, ComputeService, HostTensor};
use crate::util::json::Json;
use crate::util::timer::Stopwatch;
use crate::util::toml::Doc;

use super::checkpoint::{self, CheckpointMeta};
use super::journal::Record;
use super::metrics::Metrics;
use super::snapshot::Snapshotter;
use super::worker::{self, PhaseCtx, WorkerOutput, WorkerState};
use super::{
    apply_resume, effective_workers, load_resume, median_ms, open_durability, run_config_hash,
    DemotionEvent, RecoveryEvent, RejoinEvent, StragglerReading, TrainReport, Trainer,
};

/// Frame-size cap on the control plane. Control frames are tiny JSON, but
/// the same stream ships whole-model state blobs, which dwarf any
/// data-plane bucket — so the control cap is sized independently of
/// `transport.max_frame_bytes`.
const CONTROL_MAX_FRAME: usize = 1 << 30;


/// One event from a control-socket reader thread. Every socket gets a
/// blocking reader that feeds this into the owner's mpsc queue; all
/// *writes* stay on the owner's main thread, so no stream is ever written
/// from two threads.
enum Event {
    /// A JSON control frame from connection `id`.
    Control(usize, Json),
    /// A state blob from connection `id`.
    Blob(usize, Vec<u8>),
    /// Connection `id` hit EOF or a read error: the process behind it is
    /// gone (or unreachable, which for a training run is the same thing).
    Closed(usize),
}

fn spawn_control_reader(id: usize, mut stream: TcpStream, tx: mpsc::Sender<Event>) {
    thread::Builder::new()
        .name(format!("ctl-reader-{id}"))
        .spawn(move || {
            let mut body = Vec::new();
            loop {
                match frame::read_frame(&mut stream, CONTROL_MAX_FRAME, &mut body) {
                    Ok(Some(h)) if h.kind == frame::KIND_CONTROL => {
                        let parsed =
                            std::str::from_utf8(&body).ok().and_then(|s| Json::parse(s).ok());
                        match parsed {
                            Some(j) => {
                                if tx.send(Event::Control(id, j)).is_err() {
                                    return;
                                }
                            }
                            None => break,
                        }
                    }
                    Ok(Some(h)) if h.kind == frame::KIND_BLOB => {
                        if tx.send(Event::Blob(id, std::mem::take(&mut body))).is_err() {
                            return;
                        }
                    }
                    _ => break,
                }
            }
            let _ = tx.send(Event::Closed(id));
        })
        .expect("spawning a control reader thread");
}

fn num(x: usize) -> Json {
    Json::Num(x as f64)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------

/// One registered worker process, as the coordinator sees it.
struct WorkerConn {
    stream: TcpStream,
    /// Eligible for future phases. Cleared forever once the worker is
    /// declared dead (socket drop, stale heartbeat, or non-victim
    /// failure) — a dead machine stays dead.
    usable: bool,
    /// Control socket still writable (a casualty's phase can die while its
    /// process lives on; it still gets the final `shutdown`).
    open: bool,
    /// When the coordinator last heard a `beat` (or handed out a phase).
    last_beat: Instant,
    /// Rank-local heartbeat staleness the worker reported with that beat.
    stale_ms: u64,
    /// Data-mesh link reconnects the worker reported with its last beat.
    reconnects: u64,
    /// Last completed-step index the worker's beats have reported (the
    /// step-progress signal that distinguishes *slow* from *wedged*).
    last_step: u64,
    /// When `last_step` last moved (or the worker was handed a phase).
    last_advance: Instant,
    /// Local-work EWMA the worker reported with its last beat, ms.
    step_ms_ewma: Option<f64>,
    /// How many steps back that EWMA — stragglers are only judged once
    /// `fault.straggler.min_samples` steps have been observed.
    step_samples: u64,
}

fn new_conn(stream: TcpStream) -> WorkerConn {
    WorkerConn {
        stream,
        usable: true,
        open: true,
        last_beat: Instant::now(),
        stale_ms: 0,
        reconnects: 0,
        last_step: 0,
        last_advance: Instant::now(),
        step_ms_ewma: None,
        step_samples: 0,
    }
}

fn send_to(conns: &mut [WorkerConn], id: usize, wbuf: &mut Vec<u8>, j: &Json) {
    let c = &mut conns[id];
    if c.open && frame::write_control(&mut c.stream, wbuf, &j.to_string()).is_err() {
        c.open = false;
        c.usable = false;
    }
}

/// Geometry + schedule position of one phase attempt.
struct AttemptPlan {
    /// Fencing tag: every frame of this attempt carries it, so stragglers
    /// from an aborted attempt cannot corrupt the replay.
    seq: u64,
    workers: usize,
    per_worker: usize,
    steps: usize,
    first_step: usize,
    samples_before: u64,
    skip_steps: usize,
    attempt: usize,
    degraded: bool,
}

enum RemoteOutcome {
    /// Every rank finished and all state blobs were byte-identical;
    /// `state` is rank 0's decoded phase-boundary state and `blob` the
    /// raw bytes it was decoded from (already the checkpoint format —
    /// the snapshotter stores them without re-encoding).
    Complete {
        state: WorkerState,
        metrics: Metrics,
        blob: Vec<u8>,
        /// Stragglers the attempt confirmed (chronically over the slow
        /// threshold for the grace window) — handed to the boundary
        /// policy, never acted on mid-phase.
        stragglers: Vec<StragglerReading>,
    },
    /// The attempt lost ranks (indices local to the attempt's mesh).
    Failed { dead: Vec<usize>, err: anyhow::Error },
}

/// Mutable tracking state of one phase attempt.
struct Attempt<'a> {
    /// Connection id of each rank.
    participants: &'a [usize],
    seq: u64,
    dead: Vec<bool>,
    /// Ranks that reported `failed` (victim or casualty).
    failed: Vec<bool>,
    done_meta: Vec<Option<Metrics>>,
    blobs: Vec<Option<Vec<u8>>>,
    addrs: Vec<Option<String>>,
    started: bool,
    casualty_err: Option<anyhow::Error>,
    victim_err: Option<anyhow::Error>,
    /// Once any failure surfaces, the attempt drains survivors only until
    /// this deadline — victims unwind in bounded time, and a rank that
    /// does not is declared dead rather than waited on forever.
    drain_deadline: Option<Instant>,
    drain_budget: Duration,
    wbuf: Vec<u8>,
}

impl Attempt<'_> {
    fn rank_of(&self, id: usize) -> Option<usize> {
        self.participants.iter().position(|&w| w == id)
    }

    fn resolved(&self, r: usize) -> bool {
        self.blobs[r].is_some() || self.failed[r] || self.dead[r]
    }

    fn all_resolved(&self) -> bool {
        (0..self.dead.len()).all(|r| self.resolved(r))
    }

    fn note_failure(&mut self) {
        if self.drain_deadline.is_none() {
            self.drain_deadline = Some(Instant::now() + self.drain_budget);
        }
    }

    /// Declare `rank` dead: record the casualty, drop its worker from the
    /// registry, and tell the survivors to abort so their blocked
    /// collectives unwind instead of waiting on a silent peer.
    fn declare_dead(&mut self, conns: &mut [WorkerConn], rank: usize, err: anyhow::Error) {
        if self.dead[rank] {
            return;
        }
        eprintln!("[coordinator] rank {rank} declared dead: {err:#}");
        self.dead[rank] = true;
        conns[self.participants[rank]].usable = false;
        self.casualty_err.get_or_insert(err);
        self.note_failure();
        let abort = obj(vec![
            ("type", Json::Str("abort".into())),
            ("seq", num(self.seq as usize)),
            ("rank", num(rank)),
        ]);
        let parts = self.participants;
        for (r, &id) in parts.iter().enumerate() {
            if r != rank {
                send_to(conns, id, &mut self.wbuf, &abort);
            }
        }
    }
}

/// Drive one phase attempt across the registered worker processes.
fn run_phase_remote(
    conns: &mut [WorkerConn],
    rx: &mpsc::Receiver<Event>,
    participants: &[usize],
    ap: &AttemptPlan,
    state: &WorkerState,
    cfg: &TrainConfig,
    board: &Mutex<StatusBoard>,
) -> Result<RemoteOutcome> {
    let workers = ap.workers;
    let state_bytes = checkpoint::encode(
        state,
        CheckpointMeta {
            step: ap.first_step as u64,
            samples: ap.samples_before,
        },
    )?;
    let mut a = Attempt {
        participants,
        seq: ap.seq,
        dead: vec![false; workers],
        failed: vec![false; workers],
        done_meta: (0..workers).map(|_| None).collect(),
        blobs: (0..workers).map(|_| None).collect(),
        addrs: (0..workers).map(|_| None).collect(),
        started: false,
        casualty_err: None,
        victim_err: None,
        drain_deadline: None,
        drain_budget: if cfg.fault.enabled {
            cfg.fault.rank_timeout * 2 + Duration::from_secs(10)
        } else {
            Duration::from_secs(30)
        },
        wbuf: Vec::new(),
    };
    let rank_timeout_ms = cfg.fault.rank_timeout.as_millis() as u64;

    // Hand out the attempt: prepare frame + phase-boundary state blob.
    let mut prep_failures = Vec::new();
    for (rank, &id) in participants.iter().enumerate() {
        let prep = obj(vec![
            ("type", Json::Str("prepare".into())),
            ("seq", num(ap.seq as usize)),
            ("rank", num(rank)),
            ("workers", num(workers)),
            ("per_worker", num(ap.per_worker)),
            ("steps", num(ap.steps)),
            ("first_step", num(ap.first_step)),
            ("samples_before", Json::Num(ap.samples_before as f64)),
            ("skip_steps", num(ap.skip_steps)),
            ("attempt", num(ap.attempt)),
            ("degraded", Json::Bool(ap.degraded)),
        ]);
        let c = &mut conns[id];
        c.last_beat = Instant::now();
        c.stale_ms = 0;
        c.last_advance = Instant::now();
        let sent = c.open
            && frame::write_control(&mut c.stream, &mut a.wbuf, &prep.to_string()).is_ok()
            && frame::write_blob(&mut c.stream, &mut a.wbuf, &state_bytes).is_ok();
        if !sent {
            c.open = false;
            c.usable = false;
            prep_failures.push(rank);
        }
    }
    for rank in prep_failures {
        a.declare_dead(
            conns,
            rank,
            anyhow!("worker connection lost while preparing rank {rank}"),
        );
    }

    let tick = Duration::from_millis(50);
    let scfg = cfg.fault.straggler;
    let mut stragglers: Vec<StragglerReading> = Vec::new();
    let mut slow_since: Vec<Option<Instant>> = vec![None; workers];
    let mut flagged = vec![false; workers];
    let mut last_scan = Instant::now();
    while !a.all_resolved() {
        publish_ranks(board, conns, &a);
        if let Some(dl) = a.drain_deadline {
            if Instant::now() > dl {
                for r in 0..workers {
                    if !a.resolved(r) {
                        a.declare_dead(
                            conns,
                            r,
                            anyhow!("rank {r} did not resolve while draining a failed attempt"),
                        );
                    }
                }
                break;
            }
        }
        // Liveness + straggler scan, throttled to the tick (a busy control
        // socket keeps events flowing, so this cannot live only in the
        // recv-timeout arm). A hung worker never closes its socket — only
        // its silence gives it away. Effective staleness stacks the
        // control-hop silence on the staleness the last beat reported; a
        // rank whose beats still report *step progress* at its own recorded
        // pace is slow, not wedged, and is spared the death sentence.
        if cfg.fault.enabled && last_scan.elapsed() >= tick {
            last_scan = Instant::now();
            for r in 0..workers {
                if a.resolved(r) {
                    continue;
                }
                let c = &conns[a.participants[r]];
                let staleness = c.last_beat.elapsed().as_millis() as u64 + c.stale_ms;
                let advance_age = c.last_advance.elapsed().as_millis() as u64;
                if presumed_wedged(staleness, rank_timeout_ms, advance_age, c.step_ms_ewma) {
                    a.declare_dead(
                        conns,
                        r,
                        anyhow!(
                            "rank {r} heartbeat stale for {staleness} ms with no step \
                             progress for {advance_age} ms"
                        ),
                    );
                }
            }
            // Straggler detection is telemetry (policy acts only at the
            // boundary): a rank judged against the live-cluster median,
            // sustained over `grace`, is confirmed once per attempt.
            let judged: Vec<f64> = (0..workers)
                .filter(|&r| !a.dead[r] && conns[a.participants[r]].step_samples >= scfg.min_samples)
                .filter_map(|r| conns[a.participants[r]].step_ms_ewma)
                .collect();
            if judged.len() >= 2 {
                let med = median_ms(judged);
                for r in 0..workers {
                    if flagged[r] || a.resolved(r) {
                        continue;
                    }
                    let c = &conns[a.participants[r]];
                    let over = med > 0.0
                        && c.step_samples >= scfg.min_samples
                        && c.step_ms_ewma.is_some_and(|e| e > scfg.slow_factor * med);
                    if !over {
                        slow_since[r] = None;
                        continue;
                    }
                    let since = *slow_since[r].get_or_insert_with(Instant::now);
                    if since.elapsed() >= scfg.grace {
                        flagged[r] = true;
                        stragglers.push(StragglerReading {
                            rank: r,
                            step_ms_ewma: c.step_ms_ewma.unwrap_or(0.0),
                            median_ms: med,
                        });
                        eprintln!(
                            "[coordinator] rank {r} confirmed as a straggler \
                             ({:.1} ms/step vs {med:.1} ms median)",
                            c.step_ms_ewma.unwrap_or(0.0)
                        );
                    }
                }
            }
        }
        let ev = match rx.recv_timeout(tick) {
            Ok(ev) => ev,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => bail!("control event channel closed"),
        };
        match ev {
            Event::Closed(id) => {
                conns[id].open = false;
                conns[id].usable = false;
                if let Some(rank) = a.rank_of(id) {
                    if !a.resolved(rank) {
                        a.declare_dead(
                            conns,
                            rank,
                            anyhow!("worker {id} (rank {rank}) dropped its control connection"),
                        );
                    }
                }
            }
            Event::Blob(id, bytes) => {
                // A blob is only meaningful right after its `done` frame
                // (same ordered stream); anything else is a straggler.
                if let Some(rank) = a.rank_of(id) {
                    if a.done_meta[rank].is_some() && a.blobs[rank].is_none() {
                        a.blobs[rank] = Some(bytes);
                    }
                }
            }
            Event::Control(id, j) => {
                let Some(rank) = a.rank_of(id) else { continue };
                let Ok(ty) = j.get("type").and_then(|t| t.as_str()) else {
                    continue;
                };
                let seq_ok = j.opt("seq").and_then(|s| s.as_usize().ok()) == Some(ap.seq as usize);
                if !seq_ok {
                    continue; // straggler from an aborted attempt
                }
                match ty {
                    "ready" => {
                        if let Ok(addr) = j.get("addr").and_then(|x| x.as_str()) {
                            a.addrs[rank] = Some(addr.to_string());
                        }
                        if !a.started && a.addrs.iter().all(|x| x.is_some()) {
                            let list: Vec<Json> = a
                                .addrs
                                .iter()
                                .map(|x| Json::Str(x.clone().expect("checked above")))
                                .collect();
                            let start = obj(vec![
                                ("type", Json::Str("start".into())),
                                ("seq", num(ap.seq as usize)),
                                ("addrs", Json::Arr(list)),
                            ]);
                            let parts = a.participants;
                            for &pid in parts {
                                send_to(conns, pid, &mut a.wbuf, &start);
                            }
                            a.started = true;
                        }
                    }
                    "beat" => {
                        let c = &mut conns[id];
                        c.last_beat = Instant::now();
                        c.stale_ms =
                            j.opt("stale_ms").and_then(|s| s.as_f64().ok()).unwrap_or(0.0) as u64;
                        c.reconnects = j
                            .opt("reconnects")
                            .and_then(|s| s.as_f64().ok())
                            .unwrap_or(0.0) as u64;
                        // Step-progress telemetry: a changed completed-step
                        // index is what lets the monitor tell *advancing
                        // slowly* apart from *wedged*.
                        if let Some(step) =
                            j.opt("step").and_then(|s| s.as_f64().ok()).map(|s| s as u64)
                        {
                            if step != c.last_step {
                                c.last_step = step;
                                c.last_advance = Instant::now();
                            }
                        }
                        if let Some(ms) = j.opt("step_ms").and_then(|s| s.as_f64().ok()) {
                            c.step_ms_ewma = Some(ms);
                        }
                        if let Some(n) = j.opt("step_samples").and_then(|s| s.as_f64().ok()) {
                            c.step_samples = n as u64;
                        }
                    }
                    "done" => {
                        let metrics = match j.opt("metrics") {
                            Some(m) => Metrics::from_wire(m)
                                .with_context(|| format!("decoding rank {rank}'s metrics"))?,
                            None => Metrics::default(),
                        };
                        a.done_meta[rank] = Some(metrics);
                    }
                    "failed" => {
                        let victim = matches!(j.opt("victim"), Some(Json::Bool(true)));
                        let msg = j
                            .opt("err")
                            .and_then(|e| e.as_str().ok())
                            .unwrap_or("unknown error")
                            .to_string();
                        a.failed[rank] = true;
                        a.note_failure();
                        if victim {
                            a.victim_err.get_or_insert(anyhow!("rank {rank}: {msg}"));
                        } else {
                            a.declare_dead(conns, rank, anyhow!("rank {rank} failed: {msg}"));
                        }
                    }
                    _ => {}
                }
            }
        }
    }

    publish_ranks(board, conns, &a);
    let dead_list: Vec<usize> = (0..workers).filter(|&r| a.dead[r]).collect();
    if dead_list.is_empty() && a.casualty_err.is_none() && a.victim_err.is_none() {
        // Replicated-parameter invariant, process edition: identical
        // reduced gradients + identical updates must leave every rank's
        // exported state bit-identical — and the checkpoint encoding is
        // deterministic, so bit-identical state means byte-identical blobs.
        if let Some((first, rest)) = a.blobs.split_first() {
            for (i, b) in rest.iter().enumerate() {
                if b != first {
                    bail!(
                        "replicated-parameter invariant violated: rank {} diverged from \
                         rank 0 after step {}",
                        i + 1,
                        ap.first_step + ap.steps
                    );
                }
            }
        }
        let bytes = a.blobs[0].take().expect("complete attempt lost rank 0's blob");
        let (st, _meta) =
            checkpoint::decode(&bytes).context("decoding rank 0's phase-boundary state")?;
        let metrics = a.done_meta[0].take().unwrap_or_default();
        Ok(RemoteOutcome::Complete {
            state: st,
            metrics,
            blob: bytes,
            stragglers,
        })
    } else {
        let err = a
            .casualty_err
            .or(a.victim_err)
            .unwrap_or_else(|| anyhow!("phase attempt failed with no recorded error"));
        Ok(RemoteOutcome::Failed { dead: dead_list, err })
    }
}

/// Between attempts: fold queued connection deaths into the registry and
/// drop any stragglers from the attempt that just ended.
fn drain_idle_events(rx: &mpsc::Receiver<Event>, conns: &mut [WorkerConn]) {
    while let Ok(ev) = rx.try_recv() {
        if let Event::Closed(id) = ev {
            conns[id].open = false;
            conns[id].usable = false;
        }
    }
}

// ---------------------------------------------------------------------
// Rejoin: the control socket stays open after registration
// ---------------------------------------------------------------------

fn is_hello(body: &[u8]) -> bool {
    let Ok(s) = std::str::from_utf8(body) else { return false };
    let Ok(j) = Json::parse(s) else { return false };
    matches!(j.get("type").and_then(|t| t.as_str()), Ok("hello"))
}

/// Keep accepting on the control listener after registration closed, so a
/// restarted `flashsgd worker --join` can re-register mid-run. Each dialer
/// that completes the hello handshake is queued for the coordinator's main
/// loop, which admits it at the next phase boundary. Runs for the life of
/// the process (like the http thread); exits if the queue is dropped.
fn spawn_join_door(listener: TcpListener, join_tx: mpsc::Sender<TcpStream>) {
    thread::Builder::new()
        .name("join-door".into())
        .spawn(move || {
            let mut body = Vec::new();
            loop {
                let Ok((mut s, from)) = listener.accept() else { return };
                s.set_nodelay(true).ok();
                // A bounded handshake: a port-scanner that never says hello
                // must not wedge the door shut for a real rejoiner.
                let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
                let ok = matches!(
                    frame::read_frame(&mut s, CONTROL_MAX_FRAME, &mut body),
                    Ok(Some(h)) if h.kind == frame::KIND_CONTROL && is_hello(&body)
                );
                if !ok {
                    eprintln!("[coordinator] ignoring a dialer at {from} that sent no hello");
                    continue;
                }
                let _ = s.set_read_timeout(None);
                if join_tx.send(s).is_err() {
                    return;
                }
            }
        })
        .expect("spawning the join-door thread");
}

/// Welcome one queued rejoiner under a fresh connection id (a dead
/// machine's id stays dead — arrival order still fixes rank order).
fn admit_one(
    mut s: TcpStream,
    conns: &mut Vec<WorkerConn>,
    config_text: &str,
    tx: &mpsc::Sender<Event>,
    wbuf: &mut Vec<u8>,
) -> Option<usize> {
    let id = conns.len();
    let welcome = obj(vec![
        ("type", Json::Str("welcome".into())),
        ("worker", num(id)),
        ("config", Json::Str(config_text.to_string())),
    ]);
    if frame::write_control(&mut s, wbuf, &welcome.to_string()).is_err() {
        return None;
    }
    let reader = s.try_clone().ok()?;
    spawn_control_reader(id, reader, tx.clone());
    conns.push(new_conn(s));
    eprintln!("[coordinator] worker {id} rejoined");
    Some(id)
}

/// Admit every queued rejoiner; with a `deadline`, keep waiting for more
/// while the usable worker count is still short of `target_usable` (the
/// `fault.rejoin_grace` window — a replay that waits for its replacement
/// runs at full width, which is what keeps the final checkpoint identical
/// to an undisturbed run's).
fn admit_rejoiners(
    join_rx: &mpsc::Receiver<TcpStream>,
    conns: &mut Vec<WorkerConn>,
    config_text: &str,
    tx: &mpsc::Sender<Event>,
    wbuf: &mut Vec<u8>,
    target_usable: usize,
    deadline: Option<Instant>,
) -> Vec<usize> {
    let mut admitted = Vec::new();
    loop {
        while let Ok(s) = join_rx.try_recv() {
            if let Some(id) = admit_one(s, conns, config_text, tx, wbuf) {
                admitted.push(id);
            }
        }
        let usable = conns.iter().filter(|c| c.usable).count();
        let Some(dl) = deadline else { return admitted };
        if usable >= target_usable {
            return admitted;
        }
        let now = Instant::now();
        if now >= dl {
            return admitted;
        }
        match join_rx.recv_timeout((dl - now).min(Duration::from_millis(100))) {
            Ok(s) => {
                if let Some(id) = admit_one(s, conns, config_text, tx, wbuf) {
                    admitted.push(id);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return admitted,
        }
    }
}

/// Per-rank liveness of the current attempt, as served on `/status`.
struct RankStatus {
    /// Connection id of the worker process behind this rank.
    worker: usize,
    usable: bool,
    /// Control-hop silence: ms since the coordinator last heard a beat.
    beat_age_ms: u64,
    /// Rank-local staleness the worker reported with that beat.
    stale_ms: u64,
    /// Data-mesh link reconnects the worker has survived so far.
    reconnects: u64,
    /// Local-work EWMA the rank last reported, ms (`null` until it has
    /// completed a step).
    step_ms_ewma: Option<f64>,
    /// `step_ms_ewma / median(live ranks)` — > 1 means slower than the
    /// cluster, `fault.straggler.slow_factor` is the demotion threshold.
    straggler_score: Option<f64>,
}

/// Live run state served over the HTTP endpoint.
struct StatusBoard {
    state: String,
    workers_expected: usize,
    workers_joined: usize,
    workers_live: usize,
    phase: usize,
    phases_total: usize,
    step: usize,
    recoveries: usize,
    rejoins: usize,
    demotions: usize,
    last_loss: f64,
    /// Step of the newest durable snapshot (`null` until one lands).
    last_snapshot: Option<u64>,
    /// Byte length of the run journal (0 when durability is off) — a
    /// monotone progress cursor an external watcher can poll.
    journal_bytes: u64,
    ranks: Vec<RankStatus>,
    /// Pre-rendered `GET /metrics` body (the merged metrics report).
    metrics_json: String,
}

impl StatusBoard {
    fn new(workers_expected: usize, phases_total: usize) -> Self {
        Self {
            state: "starting".into(),
            workers_expected,
            workers_joined: 0,
            workers_live: 0,
            phase: 0,
            phases_total,
            step: 0,
            recoveries: 0,
            rejoins: 0,
            demotions: 0,
            last_loss: f64::NAN,
            last_snapshot: None,
            journal_bytes: 0,
            ranks: Vec::new(),
            metrics_json: r#"{"steps":[],"evals":[]}"#.into(),
        }
    }

    fn status_json(&self) -> String {
        let ranks: Vec<Json> = self
            .ranks
            .iter()
            .map(|r| {
                obj(vec![
                    ("worker", num(r.worker)),
                    ("usable", Json::Bool(r.usable)),
                    ("beat_age_ms", Json::Num(r.beat_age_ms as f64)),
                    ("stale_ms", Json::Num(r.stale_ms as f64)),
                    ("reconnects", Json::Num(r.reconnects as f64)),
                    (
                        "step_ms_ewma",
                        r.step_ms_ewma.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    (
                        "straggler_score",
                        r.straggler_score.map(Json::Num).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        obj(vec![
            ("state", Json::Str(self.state.clone())),
            ("workers_expected", num(self.workers_expected)),
            ("workers_joined", num(self.workers_joined)),
            ("workers_live", num(self.workers_live)),
            ("phase", num(self.phase)),
            ("phases_total", num(self.phases_total)),
            ("step", num(self.step)),
            ("recoveries", num(self.recoveries)),
            ("rejoins", num(self.rejoins)),
            ("demotions", num(self.demotions)),
            (
                "last_loss",
                if self.last_loss.is_finite() {
                    Json::Num(self.last_loss)
                } else {
                    Json::Null
                },
            ),
            (
                "last_snapshot",
                match self.last_snapshot {
                    Some(step) => Json::Num(step as f64),
                    None => Json::Null,
                },
            ),
            ("journal_bytes", Json::Num(self.journal_bytes as f64)),
            ("ranks", Json::Arr(ranks)),
        ])
        .to_string()
    }
}

/// Refresh the board's per-rank liveness from the attempt in flight.
fn publish_ranks(board: &Mutex<StatusBoard>, conns: &[WorkerConn], a: &Attempt<'_>) {
    // Straggler scores are relative to the live cluster: each rank's EWMA
    // over the median of every live rank that has reported one.
    let live: Vec<f64> = a
        .participants
        .iter()
        .enumerate()
        .filter(|&(r, &id)| !a.dead[r] && conns[id].step_ms_ewma.is_some())
        .filter_map(|(_, &id)| conns[id].step_ms_ewma)
        .collect();
    let med = if live.is_empty() { 0.0 } else { median_ms(live) };
    let ranks = a
        .participants
        .iter()
        .enumerate()
        .map(|(r, &id)| {
            let c = &conns[id];
            RankStatus {
                worker: id,
                usable: c.usable && !a.dead[r],
                beat_age_ms: c.last_beat.elapsed().as_millis() as u64,
                stale_ms: c.stale_ms,
                reconnects: c.reconnects,
                step_ms_ewma: c.step_ms_ewma,
                straggler_score: match (c.step_ms_ewma, med > 0.0) {
                    (Some(e), true) => Some(e / med),
                    _ => None,
                },
            }
        })
        .collect();
    board.lock().unwrap().ranks = ranks;
}

/// Bind a TCP listener with `SO_REUSEADDR` set, so a *restarted*
/// coordinator can reclaim its control and status ports immediately.
/// Without the option, the previous instance's dying worker connections
/// hold the port in `TIME_WAIT`/`FIN_WAIT` for up to ~60 s and the
/// crash-resume path stalls on `EADDRINUSE` — longer than any sane
/// `coordinator_grace_ms`. The raw FFI goes straight at the platform C
/// library (the dependency tree has no libc crate): std's
/// `TcpListener::bind` offers no hook between `socket()` and `bind()`.
#[cfg(target_os = "linux")]
fn listen_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    use std::net::SocketAddr;
    use std::os::raw::{c_int, c_uint, c_void};
    use std::os::unix::io::FromRawFd;

    // Non-IPv4 specs (hostnames, IPv6) fall back to the std path: the
    // reuse guarantee is only needed on the fixed numeric addresses a
    // coordinator publishes to its workers.
    let Ok(SocketAddr::V4(v4)) = addr.parse::<SocketAddr>() else {
        return TcpListener::bind(addr);
    };

    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_void,
            len: c_uint,
        ) -> c_int;
        fn bind(fd: c_int, addr: *const c_void, len: c_uint) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }
    const AF_INET: c_int = 2;
    const SOCK_STREAM: c_int = 1;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    /// `struct sockaddr_in` (Linux ABI): family, then port and address in
    /// network byte order, then padding.
    #[repr(C)]
    struct SockaddrIn {
        family: u16,
        port_be: u16,
        addr_be: u32,
        zero: [u8; 8],
    }

    // Capture errno, close the half-made socket, hand back the error.
    let fail = |fd: c_int| -> std::io::Error {
        let e = std::io::Error::last_os_error();
        unsafe { close(fd) };
        e
    };
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        let one: c_int = 1;
        if setsockopt(
            fd,
            SOL_SOCKET,
            SO_REUSEADDR,
            (&one as *const c_int).cast(),
            std::mem::size_of::<c_int>() as c_uint,
        ) != 0
        {
            return Err(fail(fd));
        }
        let sin = SockaddrIn {
            family: AF_INET as u16,
            port_be: v4.port().to_be(),
            // octets() is already big-endian byte order; store verbatim
            addr_be: u32::from_ne_bytes(v4.ip().octets()),
            zero: [0; 8],
        };
        if bind(
            fd,
            (&sin as *const SockaddrIn).cast(),
            std::mem::size_of::<SockaddrIn>() as c_uint,
        ) != 0
        {
            return Err(fail(fd));
        }
        if listen(fd, 128) != 0 {
            return Err(fail(fd));
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

#[cfg(not(target_os = "linux"))]
fn listen_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    TcpListener::bind(addr)
}

/// Serve `GET /status` and `GET /metrics` as JSON over plain HTTP/1.0.
/// The accept loop runs on a daemon thread for the life of the process.
fn serve_http(addr: &str, board: Arc<Mutex<StatusBoard>>) -> Result<()> {
    let listener = listen_reuseaddr(addr)
        .with_context(|| format!("binding the http status endpoint on {addr}"))?;
    let bound = listener.local_addr()?;
    eprintln!("[coordinator] status endpoint at http://{bound}/status");
    thread::Builder::new()
        .name("http-status".into())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut s) = conn else { continue };
                let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
                let mut req = [0u8; 1024];
                let n = s.read(&mut req).unwrap_or(0);
                let line = String::from_utf8_lossy(&req[..n]);
                let path = line.split_whitespace().nth(1).unwrap_or("/").to_string();
                let (code, body) = {
                    let b = board.lock().unwrap();
                    match path.as_str() {
                        "/" | "/status" => ("200 OK", b.status_json()),
                        "/metrics" => ("200 OK", b.metrics_json.clone()),
                        _ => ("404 Not Found", r#"{"error":"not found"}"#.to_string()),
                    }
                };
                let _ = write!(
                    s,
                    "HTTP/1.0 {code}\r\nContent-Type: application/json\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                );
            }
        })
        .context("spawning the http status thread")?;
    Ok(())
}

/// Run the coordinator process: wait for workers on `cfg.transport.bind`,
/// drive the phase schedule across them, and return the same
/// [`TrainReport`] the in-process trainer produces. `config_text` is the
/// TOML the config was parsed from — it is shipped verbatim to every
/// worker, so all processes train the identical configuration.
/// `resume_from` takes a checkpoint file or a durable run directory
/// (journal + snapshots) — the crash/resume path.
pub fn run_coordinator(
    cfg: &TrainConfig,
    config_text: &str,
    save_to: Option<&Path>,
    resume_from: Option<&Path>,
) -> Result<TrainReport> {
    let trainer = Trainer::new(cfg.clone())?;
    let mut plans = trainer.plan_phases();
    if plans.is_empty() {
        bail!("schedule produced zero steps");
    }
    let arch = trainer.manifest.arch(&cfg.arch)?.clone();

    // Crash/resume: restore the newest valid snapshot (or a checkpoint
    // file) and drop the already-trained prefix of the schedule — the
    // same journal verification and plan trimming as the in-process
    // trainer, so a run started in one mode resumes in the other.
    let cfg_hash = run_config_hash(cfg);
    let resuming_dir = resume_from.is_some_and(|p| p.is_dir());
    let resumed = resume_from
        .map(|p| load_resume(p, cfg_hash))
        .transpose()?
        .flatten();
    if let Some((st, meta)) = &resumed {
        apply_resume(&mut plans, &arch, st, meta)?;
    }

    // Durability: run journal + background snapshotter when
    // `[checkpoint] dir` is set.
    let durable = open_durability(cfg, cfg_hash, resuming_dir)?;
    let journal = durable.as_ref().map(|d| d.journal.clone());
    let mut snapshotter = durable.map(|d| d.snapshotter);

    let n_workers = plans.iter().map(|p| p.workers).max().unwrap_or(1);

    let board = Arc::new(Mutex::new(StatusBoard::new(n_workers, plans.len())));
    if !cfg.transport.http.is_empty() {
        serve_http(&cfg.transport.http, board.clone())?;
    }

    // One local compute lane: `init` for the initial parameters, eval for
    // the final report. All training compute happens in the workers.
    let eval_name = arch.eval_exec()?.name.clone();
    let svc = ComputeService::start_pool(
        BackendSpec::Reference,
        trainer.manifest.clone(),
        &cfg.arch,
        &["init", eval_name.as_str()],
        1,
    )
    .context("starting the coordinator's compute lane")?;
    let client = svc.client();
    let mut sw = Stopwatch::new();

    // Initial state: the resumed snapshot, or the deterministic He init
    // (paper init per [10]). Because snapshots are exact phase-boundary
    // states, a resume replays from a boundary — the remaining phases ship
    // the restored blob instead of the init artifact and the sample stream
    // continues at the saved position.
    let mut state = match resumed {
        Some((st, _)) => st,
        None => {
            let params = client.run(
                &format!("{}/init", cfg.arch),
                vec![HostTensor::i32(vec![1], vec![cfg.seed as i32])],
            )?;
            let momenta: Vec<HostTensor> = params
                .iter()
                .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
                .collect();
            let bn_running: Vec<HostTensor> = arch
                .bn_layers
                .iter()
                .map(|b| HostTensor::f32(vec![2, b.width], vec![0.0; 2 * b.width]))
                .collect();
            WorkerState {
                params,
                momenta,
                bn_running,
                bn_steps: 0,
            }
        }
    };

    // Registration: accept exactly the widest phase's worker count, in
    // arrival order (arrival order fixes rank order for every phase).
    let listener = listen_reuseaddr(&cfg.transport.bind).with_context(|| {
        format!(
            "binding the coordinator control socket on {}",
            cfg.transport.bind
        )
    })?;
    let bound = listener.local_addr()?;
    eprintln!("[coordinator] waiting for {n_workers} workers on {bound}");
    board.lock().unwrap().state = "waiting".into();

    let (tx, rx) = mpsc::channel();
    let mut conns: Vec<WorkerConn> = Vec::with_capacity(n_workers);
    let mut wbuf = Vec::new();
    let mut body = Vec::new();
    for id in 0..n_workers {
        let (mut s, from) = listener.accept().context("accepting a worker")?;
        s.set_nodelay(true).ok();
        let h = frame::read_frame(&mut s, CONTROL_MAX_FRAME, &mut body)?
            .ok_or_else(|| anyhow!("worker at {from} closed before hello"))?;
        if h.kind != frame::KIND_CONTROL {
            bail!("worker at {from} sent frame kind {} before hello", h.kind);
        }
        let hello = Json::parse(std::str::from_utf8(&body)?)?;
        if hello.get("type")?.as_str()? != "hello" {
            bail!("worker at {from} sent {:?} before hello", hello.to_string());
        }
        let welcome = obj(vec![
            ("type", Json::Str("welcome".into())),
            ("worker", num(id)),
            ("config", Json::Str(config_text.to_string())),
        ]);
        frame::write_control(&mut s, &mut wbuf, &welcome.to_string())?;
        spawn_control_reader(id, s.try_clone()?, tx.clone());
        conns.push(new_conn(s));
        eprintln!("[coordinator] worker {id} joined from {from} ({}/{n_workers})", id + 1);
        board.lock().unwrap().workers_joined = id + 1;
    }

    // Registration is over, but the door stays open: late dialers are
    // rejoiners, admitted at phase boundaries.
    let (join_tx, join_rx) = mpsc::channel();
    spawn_join_door(listener.try_clone().context("cloning the control listener")?, join_tx);

    let mut all_metrics = Metrics::default();
    let mut restarts_used = 0usize;
    let mut recoveries: Vec<RecoveryEvent> = Vec::new();
    let mut rejoins: Vec<RejoinEvent> = Vec::new();
    let mut demotions: Vec<DemotionEvent> = Vec::new();
    let mut seq: u64 = 0;
    for (pi, plan) in plans.iter().enumerate() {
        let global_batch = plan.per_worker * plan.workers;
        let mut attempt = 0usize;
        loop {
            drain_idle_events(&rx, &mut conns);
            // Phase boundary: admit rejoiners before re-planning, so a
            // replacement that is already back (or arrives within the
            // grace) restores the mesh to full width for this attempt.
            let usable_pre = conns.iter().filter(|c| c.usable).count();
            let grace_deadline = if cfg.fault.enabled
                && usable_pre < plan.workers
                && cfg.fault.rejoin_grace > Duration::ZERO
            {
                Some(Instant::now() + cfg.fault.rejoin_grace)
            } else {
                None
            };
            let admitted = admit_rejoiners(
                &join_rx,
                &mut conns,
                config_text,
                &tx,
                &mut wbuf,
                plan.workers,
                grace_deadline,
            );
            if !admitted.is_empty() {
                let usable_post = conns.iter().filter(|c| c.usable).count();
                let before = effective_workers(
                    &arch,
                    plan.workers,
                    n_workers.saturating_sub(usable_pre),
                    global_batch,
                    cfg,
                )
                .unwrap_or_else(|_| usable_pre.min(plan.workers));
                let after = effective_workers(
                    &arch,
                    plan.workers,
                    n_workers.saturating_sub(usable_post),
                    global_batch,
                    cfg,
                )?;
                // Write-ahead: the admission is durable before the attempt
                // that runs at the restored width.
                if let Some(j) = &journal {
                    j.lock().unwrap().append(&Record::Rejoin {
                        phase: pi,
                        workers: after,
                    })?;
                }
                for &w in &admitted {
                    rejoins.push(RejoinEvent {
                        phase_first_step: plan.first_step,
                        worker: w,
                        workers_before: before,
                        workers_after: after,
                        per_worker_after: global_batch / after,
                    });
                }
                board.lock().unwrap().rejoins = rejoins.len();
                eprintln!(
                    "[coordinator] rejoin: phase at step {} re-planned {before} -> {after} ranks",
                    plan.first_step
                );
            }
            let usable = conns.iter().filter(|c| c.usable).count();
            let lost = n_workers.saturating_sub(usable);
            let workers = effective_workers(&arch, plan.workers, lost, global_batch, cfg)?;
            let per_worker = global_batch / workers;
            let degraded = workers != plan.workers;
            let participants: Vec<usize> = conns
                .iter()
                .enumerate()
                .filter(|(_, c)| c.usable)
                .map(|(i, _)| i)
                .take(workers)
                .collect();
            if participants.len() < workers {
                bail!(
                    "phase at step {} needs {workers} workers but only {} are alive",
                    plan.first_step,
                    participants.len()
                );
            }
            seq += 1;
            let ap = AttemptPlan {
                seq,
                workers,
                per_worker,
                steps: plan.steps,
                first_step: plan.first_step,
                samples_before: plan.samples_before,
                skip_steps: plan.skipped,
                attempt,
                degraded,
            };
            {
                let mut b = board.lock().unwrap();
                b.state = "running".into();
                b.phase = pi + 1;
                b.step = plan.first_step;
                b.workers_live = usable;
            }
            eprintln!(
                "[coordinator] phase {}/{}: {} steps × {workers} ranks × {per_worker}/rank \
                 (attempt {attempt})",
                pi + 1,
                plans.len(),
                plan.steps
            );
            // Write-ahead: the phase start is durable before any step runs.
            if let Some(j) = &journal {
                j.lock().unwrap().append(&Record::PhaseStart {
                    phase: pi,
                    attempt: attempt as u32,
                    step: plan.first_step as u64,
                    samples: plan.samples_before,
                    workers,
                })?;
            }
            match run_phase_remote(&mut conns, &rx, &participants, &ap, &state, cfg, &board)? {
                RemoteOutcome::Complete { state: st, metrics, blob, stragglers } => {
                    all_metrics.merge(metrics);
                    state = st;
                    // Straggler demotion: acted on here — after the phase
                    // completed cleanly — so no collective is ever aborted
                    // and no restart budget is burned. Under `demote` with
                    // a rejoin grace the rank is readmitted on the spot
                    // (the event is the record; the width never changes).
                    // Otherwise the worker is retired like a dead machine
                    // and the next boundary re-plans around it — though a
                    // demoted (not evicted) process may still come back
                    // through the join door.
                    if cfg.fault.enabled
                        && cfg.fault.straggler.policy != StragglerPolicy::Observe
                    {
                        for s in &stragglers {
                            let evicted =
                                cfg.fault.straggler.policy == StragglerPolicy::Evict;
                            let readmitted = !evicted && !cfg.fault.rejoin_grace.is_zero();
                            if !readmitted {
                                conns[participants[s.rank]].usable = false;
                            }
                            eprintln!(
                                "[coordinator] rank {} (worker {}) {} at step {} \
                                 ({:.1} ms/step vs {:.1} ms median)",
                                s.rank,
                                participants[s.rank],
                                if evicted {
                                    "evicted as a straggler"
                                } else if readmitted {
                                    "demoted and readmitted as a straggler"
                                } else {
                                    "demoted as a straggler"
                                },
                                plan.first_step + plan.steps,
                                s.step_ms_ewma,
                                s.median_ms,
                            );
                            demotions.push(DemotionEvent {
                                phase_first_step: plan.first_step + plan.steps,
                                rank: s.rank,
                                step_ms_ewma: s.step_ms_ewma,
                                median_ms: s.median_ms,
                                evicted,
                                readmitted,
                            });
                        }
                        board.lock().unwrap().demotions = demotions.len();
                    }
                    // Boundary snapshot: rank 0's done-blob is already the
                    // exact checkpoint byte format — hand it to the
                    // background writer unre-encoded and move on.
                    if let Some(s) = &mut snapshotter {
                        s.offer_bytes(
                            CheckpointMeta {
                                step: (plan.first_step + plan.steps) as u64,
                                samples: plan.samples_before
                                    + (plan.steps * plan.per_worker * plan.workers) as u64,
                            },
                            move || blob,
                        );
                    }
                    let last_snapshot = snapshotter.as_ref().and_then(|s| s.stats().last_step);
                    let journal_bytes = journal
                        .as_ref()
                        .and_then(|j| j.lock().unwrap().len_bytes().ok())
                        .unwrap_or(0);
                    let mut b = board.lock().unwrap();
                    b.last_loss = all_metrics.steps.last().map(|s| s.loss).unwrap_or(f64::NAN);
                    b.metrics_json = all_metrics.to_json().to_string();
                    b.last_snapshot = last_snapshot;
                    b.journal_bytes = journal_bytes;
                    break;
                }
                RemoteOutcome::Failed { dead, err } => {
                    let err = err.context(format!(
                        "phase at step {} failed (attempt {attempt}, {workers} workers, \
                         dead ranks {dead:?})",
                        plan.first_step
                    ));
                    if worker::error_is_non_finite(&err) {
                        // Deterministic: a replay from the same boundary
                        // state reproduces the same NaN/Inf — fail now
                        // instead of burning the restart budget.
                        return Err(err.context(
                            "numeric health guard tripped (deterministic — not retried)",
                        ));
                    }
                    if !cfg.fault.enabled {
                        return Err(err);
                    }
                    if dead.is_empty() {
                        return Err(err);
                    }
                    if restarts_used >= cfg.fault.max_restarts {
                        return Err(err.context(format!(
                            "fault.max_restarts ({}) exhausted",
                            cfg.fault.max_restarts
                        )));
                    }
                    restarts_used += 1;
                    let usable_now = conns.iter().filter(|c| c.usable).count();
                    let new_workers = effective_workers(
                        &arch,
                        plan.workers,
                        n_workers.saturating_sub(usable_now),
                        global_batch,
                        cfg,
                    )
                    .map_err(|e| e.context(err))?;
                    // Write-ahead: the recovery is durable before the
                    // re-plan it describes is adopted.
                    if let Some(j) = &journal {
                        j.lock().unwrap().append(&Record::Recovery {
                            phase: pi,
                            dead: dead.clone(),
                        })?;
                    }
                    recoveries.push(RecoveryEvent {
                        phase_first_step: plan.first_step,
                        dead_ranks: dead,
                        workers_before: workers,
                        workers_after: new_workers,
                        per_worker_after: global_batch / new_workers,
                    });
                    board.lock().unwrap().recoveries = recoveries.len();
                    eprintln!(
                        "[coordinator] recovery: replaying the phase at step {} on \
                         {new_workers} ranks",
                        plan.first_step
                    );
                    attempt += 1;
                }
            }
        }
    }

    // The run is over: release every process that still has a socket.
    let bye = obj(vec![("type", Json::Str("shutdown".into()))]);
    for id in 0..conns.len() {
        send_to(&mut conns, id, &mut wbuf, &bye);
    }

    // Final evaluation + checkpoint, exactly as the in-process trainer.
    let dataset = SynthDataset::new(
        cfg.seed,
        arch.num_classes,
        arch.image_size,
        arch.image_channels,
        cfg.train_size,
        (cfg.train_size / 4).max(arch.num_classes),
    );
    let total_steps = all_metrics.steps.last().map(|s| s.step + 1).unwrap_or(0);
    let final_eval = match all_metrics.evals.last() {
        Some(e) if e.step == total_steps => Some(e.clone()),
        _ => {
            let e = trainer
                .evaluate(&client, &arch, &dataset, &state, total_steps)
                .ok();
            if let Some(e) = &e {
                all_metrics.push_eval(e.clone());
            }
            e
        }
    };
    if let Some(path) = save_to {
        let last = plans.last().unwrap();
        let meta = CheckpointMeta {
            step: (last.first_step + last.steps) as u64,
            samples: last.samples_before + (last.steps * last.per_worker * last.workers) as u64,
        };
        checkpoint::save(path, &state, meta)
            .with_context(|| format!("saving checkpoint to {path:?}"))?;
    }

    // Seal the durable run: drain the background snapshotter, then append
    // RunEnd so it is the journal's final record.
    let snapshots = snapshotter.take().map(Snapshotter::finish).unwrap_or_default();
    if let Some(j) = &journal {
        let last = plans.last().unwrap();
        j.lock().unwrap().append(&Record::RunEnd {
            step: (last.first_step + last.steps) as u64,
            samples: last.samples_before + (last.steps * last.per_worker * last.workers) as u64,
        })?;
    }

    {
        let mut b = board.lock().unwrap();
        b.state = "done".into();
        b.metrics_json = all_metrics.to_json().to_string();
        b.last_snapshot = snapshots.last_step;
    }
    let summary = all_metrics.summary();
    Ok(TrainReport {
        config_name: cfg.name.clone(),
        metrics: all_metrics,
        summary,
        final_eval,
        wall_secs: sw.lap("total"),
        lanes: 1,
        max_lane_concurrency: svc.stats().max_concurrent(),
        recoveries,
        rejoins,
        demotions,
        snapshots,
    })
}

// ---------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------

/// Keep re-dialing a coordinator that is not up yet, with the default
/// jittered exponential backoff. (The worker cannot use the `[transport]`
/// backoff keys here: the config itself arrives in the `welcome` frame,
/// after this dial succeeds.)
fn dial_coordinator(addr: &str) -> Result<TcpStream> {
    let backoff = BackoffConfig::default();
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..backoff.attempts {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                thread::sleep(backoff.delay(attempt, 0x10_1D));
            }
        }
    }
    Err(anyhow!(last.expect("at least one dial attempt"))
        .context(format!("dialing the coordinator at {addr}")))
}

fn send_failed(
    ctl: &mut TcpStream,
    wbuf: &mut Vec<u8>,
    seq: u64,
    rank: usize,
    victim: bool,
    err: &str,
) {
    let j = obj(vec![
        ("type", Json::Str("failed".into())),
        ("seq", num(seq as usize)),
        ("rank", num(rank)),
        ("victim", Json::Bool(victim)),
        ("err", Json::Str(err.to_string())),
    ]);
    let _ = frame::write_control(ctl, wbuf, &j.to_string());
}

/// How one coordinator session ended, as seen by the worker.
enum SessionEnd {
    /// The coordinator said `shutdown` — the run is over.
    Shutdown,
    /// The control link died. `grace` is the `[fault] coordinator_grace`
    /// window the session's config allows for re-registering with a
    /// restarted coordinator (zero = the pre-durability fatal behavior).
    Lost { grace: Duration },
}

/// How one phase assignment ended on the worker.
enum PhaseEnd {
    /// Phase reported (done or failed); keep serving assignments.
    Continue,
    /// The coordinator said shutdown — exit cleanly.
    Shutdown,
    /// The control link died; the phase attempt was aborted locally.
    Lost,
}

/// Run a worker process: join the coordinator at `join`, receive the run
/// configuration, then serve phase assignments until `shutdown`. Blocks
/// for the life of the run.
///
/// Orphan safety: when the control link dies and the config grants a
/// `[fault] coordinator_grace_ms` window, the worker does not exit — it
/// holds, re-dials `join` until the window closes, and re-registers with
/// a fresh `hello` (the restarted coordinator's registration loop, or a
/// surviving coordinator's join door, admits it like any joiner). Any
/// in-flight phase attempt was already aborted locally; the coordinator
/// replays it from the last durable boundary.
pub fn run_worker(join: &str) -> Result<()> {
    let mut ctl = dial_coordinator(join)?;
    loop {
        match run_worker_session(ctl)? {
            SessionEnd::Shutdown => return Ok(()),
            SessionEnd::Lost { grace } => {
                if grace.is_zero() {
                    bail!("lost the coordinator control connection");
                }
                eprintln!(
                    "[worker] lost the coordinator; holding for {} ms and re-dialing {join}",
                    grace.as_millis()
                );
                ctl = redial_within(join, grace)?;
            }
        }
    }
}

/// Re-dial the coordinator until `grace` runs out — the orphaned worker's
/// bounded hold. A coordinator restarted inside the window gets its
/// cluster back without any worker restarts; past it the worker exits.
fn redial_within(addr: &str, grace: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + grace;
    let mut last: Option<std::io::Error> = None;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
        if Instant::now() >= deadline {
            return Err(anyhow!(last.expect("at least one dial attempt")).context(format!(
                "coordinator did not come back on {addr} within the {} ms grace window",
                grace.as_millis()
            )));
        }
        thread::sleep(Duration::from_millis(200));
    }
}

/// One control-connection lifetime: hello/welcome handshake, then serve
/// phase assignments until shutdown or link loss.
fn run_worker_session(mut ctl: TcpStream) -> Result<SessionEnd> {
    ctl.set_nodelay(true).ok();
    let mut wbuf = Vec::new();
    frame::write_control(&mut ctl, &mut wbuf, r#"{"type":"hello"}"#)?;
    let mut body = Vec::new();
    let h = frame::read_frame(&mut ctl, CONTROL_MAX_FRAME, &mut body)?
        .ok_or_else(|| anyhow!("coordinator closed before welcome"))?;
    if h.kind != frame::KIND_CONTROL {
        bail!("expected a welcome control frame, got kind {}", h.kind);
    }
    let welcome = Json::parse(std::str::from_utf8(&body)?)?;
    if welcome.get("type")?.as_str()? != "welcome" {
        bail!("expected welcome, got {:?}", welcome.to_string());
    }
    let worker_id = welcome.get("worker")?.as_usize()?;
    let config_text = welcome.get("config")?.as_str()?.to_string();
    let cfg = TrainConfig::from_toml(&Doc::parse(&config_text)?)
        .context("parsing the config shipped by the coordinator")?;
    eprintln!("[worker {worker_id}] joined, config \"{}\"", cfg.name);

    let manifest = crate::runtime::builtin_manifest();
    let arch = manifest.arch(&cfg.arch)?.clone();
    let eval_name = arch.eval_exec()?.name.clone();
    // Grad executables depend on the (possibly re-planned) per-worker
    // batch, so they are loaded per-prepare rather than up front.
    let svc = ComputeService::start_pool(
        BackendSpec::Reference,
        manifest,
        &cfg.arch,
        &["apply", eval_name.as_str()],
        1,
    )
    .context("starting the worker's compute lane")?;
    let client = svc.client();
    let dataset = SynthDataset::new(
        cfg.seed,
        arch.num_classes,
        arch.image_size,
        arch.image_channels,
        cfg.train_size,
        (cfg.train_size / 4).max(arch.num_classes),
    );
    let wire = if cfg.grad_wire == "fp16" { Wire::F16 } else { Wire::F32 };

    let (tx, rx) = mpsc::channel();
    spawn_control_reader(0, ctl.try_clone()?, tx);

    let lost = || SessionEnd::Lost {
        grace: cfg.fault.coordinator_grace,
    };
    loop {
        match rx.recv() {
            Err(_) | Ok(Event::Closed(_)) => return Ok(lost()),
            Ok(Event::Blob(..)) => bail!("unexpected state blob outside a phase"),
            Ok(Event::Control(_, j)) => match j.get("type")?.as_str()? {
                "shutdown" => {
                    eprintln!("[worker {worker_id}] shutdown");
                    return Ok(SessionEnd::Shutdown);
                }
                // A straggling abort from an attempt this worker already
                // reported on — nothing is running, nothing to do.
                "abort" => {}
                "prepare" => {
                    match run_one_phase(
                        &j, &rx, &mut ctl, &mut wbuf, &cfg, &arch, &client, &dataset, wire,
                        worker_id,
                    )? {
                        PhaseEnd::Continue => {}
                        PhaseEnd::Shutdown => return Ok(SessionEnd::Shutdown),
                        PhaseEnd::Lost => return Ok(lost()),
                    }
                }
                other => bail!("unexpected control message {other:?}"),
            },
        }
    }
}

/// Execute one phase assignment end to end: decode the shipped state, form
/// the data mesh, run the phase on its own thread (pumping heartbeats and
/// relaying aborts from this one), and report the outcome. The returned
/// [`PhaseEnd`] tells the session loop whether to keep serving, exit, or
/// enter the orphaned-worker hold.
#[allow(clippy::too_many_arguments)]
fn run_one_phase(
    prep: &Json,
    rx: &mpsc::Receiver<Event>,
    ctl: &mut TcpStream,
    wbuf: &mut Vec<u8>,
    cfg: &TrainConfig,
    arch: &ArchManifest,
    client: &ComputeClient,
    dataset: &SynthDataset,
    wire: Wire,
    worker_id: usize,
) -> Result<PhaseEnd> {
    let seq = prep.get("seq")?.as_usize()? as u64;
    let rank = prep.get("rank")?.as_usize()?;
    let workers = prep.get("workers")?.as_usize()?;
    let per_worker = prep.get("per_worker")?.as_usize()?;
    let steps = prep.get("steps")?.as_usize()?;
    let first_step = prep.get("first_step")?.as_usize()?;
    let samples_before = prep.get("samples_before")?.as_f64()? as u64;
    let skip_steps = prep.get("skip_steps")?.as_usize()?;
    let attempt = prep.get("attempt")?.as_usize()?;
    let degraded = matches!(prep.opt("degraded"), Some(Json::Bool(true)));
    eprintln!(
        "[worker {worker_id}] rank {rank}/{workers}: {steps} steps × {per_worker}/rank \
         from step {first_step} (attempt {attempt})"
    );

    // The state blob follows the prepare frame on the same ordered stream.
    let state = loop {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Event::Blob(_, bytes)) => {
                break checkpoint::decode(&bytes)
                    .context("decoding the shipped phase-boundary state")?
                    .0;
            }
            Ok(Event::Control(..)) => continue, // straggler from the previous attempt
            Ok(Event::Closed(_)) | Err(_) => {
                eprintln!("[worker {worker_id}] lost the coordinator mid-prepare");
                return Ok(PhaseEnd::Lost);
            }
        }
    };

    let g = arch.grad_exec(per_worker, cfg.label_smoothing)?;
    client
        .load(&cfg.arch, &[g.name.as_str()])
        .context("loading this phase's grad executable")?;
    // The collective spec is not on the wire: every process re-resolves it
    // from the shipped config with the same deterministic elastic rule.
    let collective: Arc<dyn Collective> =
        Arc::from(collectives::by_name_elastic(&cfg.collective, workers, degraded)?);
    let ctx = Arc::new(PhaseCtx {
        arch: arch.clone(),
        collective,
        grad_wire: wire,
        lr: cfg.lr.clone(),
        label_smoothing: cfg.label_smoothing,
        weight_decay: cfg.weight_decay,
        per_worker_batch: per_worker,
        workers,
        steps,
        first_step,
        samples_before,
        skip_steps,
        dataset_size: cfg.train_size,
        eval_every: cfg.eval_every,
        eval_batches: cfg.eval_batches,
        bucket_bytes: cfg.bucket_bytes,
        attempt,
        fault: cfg.fault.clone(),
    });

    // Bind the data listener on the interface that reaches the coordinator
    // (loopback under a local coordinator, the LAN address otherwise).
    let ip = ctl.local_addr()?.ip();
    let listener = TcpListener::bind((ip, 0)).context("binding the data listener")?;
    let addr = listener.local_addr()?.to_string();
    let ready = obj(vec![
        ("type", Json::Str("ready".into())),
        ("seq", num(seq as usize)),
        ("addr", Json::Str(addr)),
    ]);
    frame::write_control(ctl, wbuf, &ready.to_string())?;

    let health = Arc::new(Health::new(workers));
    let counters = Arc::new(Counters::default());

    // Wait for start (all ranks ready) or a pre-start cancellation.
    let start_deadline = Instant::now() + Duration::from_secs(120);
    let addrs: Vec<String> = loop {
        if Instant::now() > start_deadline {
            bail!("timed out waiting for the start frame");
        }
        match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(Event::Control(_, j)) => {
                let ty = j.get("type").and_then(|t| t.as_str()).unwrap_or("").to_string();
                let seq_ok = j.opt("seq").and_then(|s| s.as_usize().ok()) == Some(seq as usize);
                match ty.as_str() {
                    "start" if seq_ok => {
                        break j
                            .get("addrs")?
                            .as_arr()?
                            .iter()
                            .map(|a| Ok(a.as_str()?.to_string()))
                            .collect::<Result<Vec<String>>>()?;
                    }
                    "abort" if seq_ok => {
                        // The attempt died before the mesh formed; report
                        // back as a victim and return to the idle loop.
                        send_failed(ctl, wbuf, seq, rank, true, "phase cancelled before start");
                        return Ok(PhaseEnd::Continue);
                    }
                    "shutdown" => return Ok(PhaseEnd::Shutdown),
                    _ => {}
                }
            }
            Ok(Event::Blob(..)) => {}
            Ok(Event::Closed(_)) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                eprintln!("[worker {worker_id}] lost the coordinator while waiting for start");
                return Ok(PhaseEnd::Lost);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
    };

    // The phase runs on its own thread so this one can pump heartbeats to
    // the coordinator and relay its abort frames into the local health
    // table (which is what unwinds a blocked collective).
    let phase = {
        let ctx = ctx.clone();
        let client = client.clone();
        let dataset = dataset.clone();
        let health = health.clone();
        // The beat pump keeps the original Arc so each heartbeat can carry
        // the link-reconnect count the data mesh has survived so far.
        let counters = counters.clone();
        let seed = cfg.seed;
        let fault_enabled = cfg.fault.enabled;
        let rank_timeout = cfg.fault.rank_timeout;
        let topts = tcp::TcpOptions {
            max_frame_bytes: cfg.transport.max_frame_bytes,
            backoff: cfg.transport.backoff.clone(),
            reconnect_attempts: cfg.transport.reconnect_attempts,
            resync_window: cfg.transport.resync_window,
            link_policy: None,
        };
        let chaos = cfg.fault.chaos.clone();
        thread::Builder::new()
            .name(format!("rank{rank}"))
            .spawn(move || -> Result<WorkerOutput> {
                let result = std::panic::catch_unwind(AssertUnwindSafe(
                    || -> Result<WorkerOutput> {
                        let inner = tcp::connect_mesh_opts(
                            rank,
                            &addrs,
                            &listener,
                            counters,
                            health.clone(),
                            &topts,
                        )?;
                        let mut ep: Box<dyn Transport> = if chaos.enabled {
                            Box::new(ChaosTransport::new(
                                inner,
                                chaos.clone(),
                                Arc::new(ChaosCounters::default()),
                            ))
                        } else {
                            Box::new(inner)
                        };
                        if fault_enabled {
                            ep.set_recv_deadline(Some(rank_timeout));
                        }
                        let mut loader =
                            Loader::new(dataset, Augment::standard(seed), rank, ctx.workers);
                        worker::run_phase(&ctx, rank, &mut *ep, &client, &mut loader, state)
                    },
                ));
                match result {
                    Ok(Ok(o)) => Ok(o),
                    Ok(Err(e)) => {
                        // Casualty vs victim, as in the in-process runner.
                        // Marking a casualty dead before its endpoint drops
                        // suppresses the clean `bye`, so peers see an
                        // unclean close and unwind.
                        if e.downcast_ref::<MeshError>().is_none() {
                            health.mark_dead(rank);
                        }
                        Err(e)
                    }
                    Err(payload) => {
                        health.mark_dead(rank);
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".to_string());
                        Err(anyhow!("rank {rank} panicked: {msg}"))
                    }
                }
            })
            .map_err(|e| anyhow!("spawning the phase thread: {e}"))?
    };

    let beat_every = if cfg.fault.enabled {
        cfg.fault.heartbeat_interval.max(Duration::from_millis(20))
    } else {
        Duration::from_millis(500)
    };
    let mut shutdown = false;
    let mut lost_coordinator = false;
    while !phase.is_finished() {
        match rx.recv_timeout(beat_every) {
            Ok(Event::Control(_, j)) => {
                let ty = j.get("type").and_then(|t| t.as_str()).unwrap_or("").to_string();
                let seq_ok = j.opt("seq").and_then(|s| s.as_usize().ok()) == Some(seq as usize);
                match ty.as_str() {
                    "abort" if seq_ok => {
                        if let Some(d) = j.opt("rank").and_then(|r| r.as_usize().ok()) {
                            if d < workers {
                                health.mark_dead(d);
                            }
                        }
                    }
                    // Shutdown mid-phase: unwind our own rank and exit.
                    "shutdown" => {
                        shutdown = true;
                        health.mark_dead(rank);
                    }
                    _ => {}
                }
            }
            Ok(Event::Closed(_)) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Coordinator gone: nobody is left to report to.
                lost_coordinator = true;
                health.mark_dead(rank);
            }
            Ok(Event::Blob(..)) => {}
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
        // Forward liveness: the rank beats its local table from inside
        // compute/recv loops; this relays how stale that is, and the
        // coordinator stacks its own control-hop silence on top. Beats
        // also carry step telemetry — the last completed step index (the
        // slow-vs-wedged signal) and the local-work EWMA (the straggler
        // signal) — because this process's Health table only tracks its
        // own rank; the coordinator is where cluster-wide medians live.
        let mut pairs = vec![
            ("type", Json::Str("beat".into())),
            ("seq", num(seq as usize)),
            ("stale_ms", Json::Num(health.millis_since_beat(rank) as f64)),
            ("reconnects", Json::Num(counters.reconnects_seen() as f64)),
        ];
        if let Some(step) = health.last_step(rank) {
            pairs.push(("step", Json::Num(step as f64)));
        }
        if let Some(ewma) = health.step_ewma_ms(rank) {
            pairs.push(("step_ms", Json::Num(ewma)));
            pairs.push(("step_samples", Json::Num(health.step_samples(rank) as f64)));
        }
        let _ = frame::write_control(ctl, wbuf, &obj(pairs).to_string());
    }

    match phase.join() {
        Ok(Ok(out)) => {
            let meta = CheckpointMeta {
                step: (first_step + steps) as u64,
                samples: samples_before + (steps * workers * per_worker) as u64,
            };
            let bytes = checkpoint::encode(&out.state, meta)?;
            let mut pairs = vec![
                ("type", Json::Str("done".into())),
                ("seq", num(seq as usize)),
                ("rank", num(rank)),
            ];
            if rank == 0 {
                pairs.push(("metrics", out.metrics.to_wire()));
            }
            let _ = frame::write_control(ctl, wbuf, &obj(pairs).to_string());
            let _ = frame::write_blob(ctl, wbuf, &bytes);
            eprintln!(
                "[worker {worker_id}] rank {rank} finished the phase at step {first_step} \
                 (+{steps})"
            );
        }
        Ok(Err(e)) => {
            let victim = e.downcast_ref::<MeshError>().is_some();
            eprintln!(
                "[worker {worker_id}] rank {rank} {}: {e:#}",
                if victim { "aborted (victim)" } else { "failed" }
            );
            send_failed(ctl, wbuf, seq, rank, victim, &format!("{e:#}"));
        }
        Err(_) => {
            send_failed(ctl, wbuf, seq, rank, false, "phase thread died outside catch_unwind");
        }
    }
    if lost_coordinator {
        return Ok(PhaseEnd::Lost);
    }
    Ok(if shutdown { PhaseEnd::Shutdown } else { PhaseEnd::Continue })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `/status` must stay machine-parseable: per-rank liveness, recovery
    /// and rejoin totals all round-trip through the JSON it serves.
    #[test]
    fn status_json_reports_per_rank_liveness_and_rejoins() {
        let mut b = StatusBoard::new(4, 3);
        b.state = "running".into();
        b.workers_live = 4;
        b.recoveries = 1;
        b.rejoins = 2;
        b.demotions = 1;
        b.last_snapshot = Some(24);
        b.journal_bytes = 512;
        b.ranks = vec![
            RankStatus {
                worker: 0,
                usable: true,
                beat_age_ms: 120,
                stale_ms: 40,
                reconnects: 3,
                step_ms_ewma: Some(31.25),
                straggler_score: Some(1.0),
            },
            RankStatus {
                worker: 4,
                usable: false,
                beat_age_ms: 9_000,
                stale_ms: 8_500,
                reconnects: 0,
                step_ms_ewma: None,
                straggler_score: None,
            },
        ];
        let j = Json::parse(&b.status_json()).expect("/status body must be valid JSON");
        assert_eq!(j.get("state").unwrap().as_str().unwrap(), "running");
        assert_eq!(j.get("workers_expected").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("recoveries").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("rejoins").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("demotions").unwrap().as_usize().unwrap(), 1);
        // NAN loss (no steps yet) serializes as null, not as invalid JSON.
        assert!(matches!(j.get("last_loss").unwrap(), Json::Null));
        assert_eq!(j.get("last_snapshot").unwrap().as_usize().unwrap(), 24);
        assert_eq!(j.get("journal_bytes").unwrap().as_usize().unwrap(), 512);
        // A board with no snapshot yet serves null, not a bogus 0.
        let fresh = Json::parse(&StatusBoard::new(1, 1).status_json()).unwrap();
        assert!(matches!(fresh.get("last_snapshot").unwrap(), Json::Null));
        let ranks = j.get("ranks").unwrap().as_arr().unwrap();
        assert_eq!(ranks.len(), 2);
        assert_eq!(ranks[0].get("worker").unwrap().as_usize().unwrap(), 0);
        assert!(matches!(ranks[0].get("usable").unwrap(), Json::Bool(true)));
        assert_eq!(ranks[0].get("beat_age_ms").unwrap().as_f64().unwrap() as u64, 120);
        assert_eq!(ranks[0].get("reconnects").unwrap().as_f64().unwrap() as u64, 3);
        // Straggler telemetry rides the same rank objects: the EWMA and
        // the median-relative score round-trip as numbers...
        assert_eq!(ranks[0].get("step_ms_ewma").unwrap().as_f64().unwrap(), 31.25);
        assert_eq!(ranks[0].get("straggler_score").unwrap().as_f64().unwrap(), 1.0);
        assert!(matches!(ranks[1].get("usable").unwrap(), Json::Bool(false)));
        assert_eq!(ranks[1].get("stale_ms").unwrap().as_f64().unwrap() as u64, 8_500);
        // ...and a rank that has not completed a step serves null, not 0
        // (a zero would read as "infinitely fast" to a median-relative
        // score consumer).
        assert!(matches!(ranks[1].get("step_ms_ewma").unwrap(), Json::Null));
        assert!(matches!(ranks[1].get("straggler_score").unwrap(), Json::Null));
    }
}
