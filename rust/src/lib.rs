//! # flash-sgd
//!
//! Reproduction of **"Massively Distributed SGD: ImageNet/ResNet-50
//! Training in a Flash"** (Mikami et al., Sony, 2018) as a Rust system
//! with pluggable compute backends:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   2D-Torus / ring / hierarchical all-reduce schedules, batch-size
//!   control, LR/momentum schedules, LARS, data pipeline, and an
//!   ABCI-scale network simulator that regenerates the paper's tables.
//!   The communication stack is split in three (where the paper runs
//!   NCCL + MPI): collective *schedules* (`collectives::{ring, torus2d,
//!   hierarchical, halving_doubling, bucketed}`) talk only to the
//!   [`collectives::Transport`] trait; the *transport* is either the
//!   in-memory mesh (`collectives::Mesh`, the default — condvar inboxes
//!   inside one process) or TCP (`collectives::TcpMesh` over loopback,
//!   `collectives::transport::tcp::connect_mesh` across processes); and
//!   the *wire codec* (`collectives::transport::frame`) frames every
//!   payload, control message and state blob with the same
//!   length-prefixed, FP16/FP32-aware format. `flashsgd coordinator` /
//!   `flashsgd worker` (`coordinator::remote`) stretch a run across OS
//!   processes on that codec, with elastic recovery when a worker
//!   *process* dies mid-phase.
//!   Gradient synchronization is **overlapped with backprop** (paper §2.2):
//!   the backend streams gradients in reverse layer order
//!   (`runtime::ComputeBackend::grad_step_streaming`), the worker
//!   all-reduces tensor-aligned buckets (`collectives::bucketed`,
//!   `TrainConfig::bucket_bytes`) while later layers are still being
//!   computed, and applies each bucket's LARS update independently —
//!   bit-identical to the serial schedule when `bucket_bytes = 0`.
//! * **Compute backends (`runtime::backend`)** — the coordinator drives a
//!   [`runtime::ComputeBackend`] through the `runtime::ComputeService`
//!   **multi-lane pool**: one backend thread per rank, with each rank's
//!   `(params, momenta)` *resident* in its lane (`import_state` /
//!   `grad_step` / `apply` / `export_state`), so ranks compute
//!   concurrently and the steady-state step ships only batches, reduced
//!   gradients and scalars — parameters cross the channel only at phase
//!   boundaries:
//!   * `runtime::ReferenceBackend` (**default**) — a pure-Rust dense
//!     ResNet-ish forward/backward with label-smoothed softmax CE and the
//!     LARS update, serving the `init` / `grad_b{B}_ls{S}` / `apply` /
//!     `eval_b{B}` contract against a synthesized in-memory
//!     [`runtime::Manifest`]. The whole training stack — multi-phase
//!     batch-size control, FP16 gradient wire, checkpoint/resume — runs
//!     and is tested under `cargo test` with no Python, no artifact files,
//!     no XLA.
//!   * `runtime::engine` (**`--features pjrt`**) — loads
//!     `artifacts/*.hlo.txt` lowered by `python/compile/aot.py` (JAX +
//!     Pallas kernels for LARS and label-smoothed softmax CE) through the
//!     PJRT C API. The workspace vendors an API stub of the `xla` crate so
//!     this feature always compiles; swap in the real crate to execute.
//!
//! The stack is **fault tolerant**: collectives are abortable (a shared
//! [`collectives::Health`] table unwinds every blocked `recv` with a typed
//! [`collectives::MeshError`] when a rank dies), a heartbeat monitor
//! detects hung or crashed ranks (`config::FaultConfig` —
//! `heartbeat_interval` / `rank_timeout` / `max_restarts`), and the
//! coordinator **elastically re-plans a failed phase on the survivors**:
//! same global batch and LR/momentum schedule, per-worker batch
//! refactored, collective re-derived (awkward survivor counts fall back
//! to ring), replayed from the phase-boundary state with the exact sample
//! stream. `simnet::ClusterModel::recovery_time` prices the
//! detect + re-plan + replay cost. See `README.md` § Fault tolerance.
//!
//! The stack is also **durable**: a run with `[checkpoint] dir` set keeps
//! a write-ahead **run journal** (`coordinator::journal` — an
//! append-only, fletcher-64-checksummed record of the config hash, phase
//! starts, recoveries, rejoins and snapshot completions, fsynced before
//! the action it describes takes effect) and writes **periodic
//! phase-boundary snapshots on a background thread** through the
//! pluggable [`storage::StorageBackend`] trait (`storage::LocalDir`
//! today, S3-shaped later), so the step loop never stalls on disk.
//! `flashsgd coordinator --resume <dir>` (or `train --resume <dir>`)
//! replays the journal plus the latest *valid* snapshot — a corrupt
//! newest file falls back to the previous good one — reconstructs the
//! exact phase/step/sample position via the same `seek_samples`
//! machinery the in-process resume uses, and re-admits **orphaned
//! workers**, which hold their mesh for `[fault] coordinator_grace_ms`
//! and re-register through the join door instead of exiting. The
//! invariant, enforced in CI: a SIGKILL'd-and-resumed run's final
//! checkpoint is byte-identical to an undisturbed run's.
//! `simnet::ClusterModel::restart_time` prices the coordinator-restart
//! path (detect + resume + replay-from-snapshot). See `README.md`
//! § Durable runs.
//!
//! The stack also **defends against stragglers** — ranks that are slow,
//! not dead, which synchronous SGD otherwise lets tax every step. Each
//! rank's *local work* time (comm excluded) feeds a per-rank EWMA in the
//! [`collectives::Health`] table; heartbeats carry step progress, so a
//! stale-but-advancing rank is never presumed wedged
//! ([`collectives::presumed_wedged`]), and `/status` scores every rank
//! against the live-cluster median. Under `[fault.straggler]`
//! (`config::StragglerConfig` — `slow_factor` / `min_samples` /
//! `grace_ms` / `policy = observe|demote|evict`) a confirmed chronic
//! straggler is drained at the next **phase boundary** via the elastic
//! re-plan (no aborted collective, no restart budget; readmitted on the
//! spot under `rejoin_grace_ms`, keeping the run byte-identical) and
//! recorded in `TrainReport::demotions`.
//! `simnet::HeteroModel` models heterogeneous clusters (per-rank
//! compute/link jitter plus a seeded straggler election shared with the
//! chaos harness), and `simnet::ClusterModel::{hetero_step_time,
//! straggler_time}` price the straggler tax and the tolerate-vs-demote
//! decision. See `README.md` § Straggler mitigation.
//!
//! Python never runs at training time under either backend; the
//! coordinator drives everything from Rust worker threads.
//!
//! See `README.md` for the build matrix and `DESIGN.md` for the full
//! inventory.

pub mod cluster;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod optim;
pub mod repro;
pub mod runtime;
pub mod sched;
pub mod simnet;
pub mod storage;
pub mod util;

/// Locate the AOT artifacts directory: `$FLASHSGD_ARTIFACTS`, then
/// `./artifacts`, then `<crate>/artifacts` (compile-time fallback so the
/// examples and benches work from any working directory). Only meaningful
/// for the `pjrt` backend; the default reference backend needs no
/// artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("FLASHSGD_ARTIFACTS") {
        return dir.into();
    }
    let local = std::path::Path::new("artifacts");
    if local.join("manifest.json").exists() {
        return local.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::cluster::{best_grid, Grid, Placement};
    pub use crate::collectives::{
        BucketPlan, Collective, HierarchicalAllReduce, Mesh, RingAllReduce, TcpMesh,
        TorusAllReduce, Transport, Wire,
    };
    pub use crate::config::{paper_run, paper_runs, TrainConfig};
    pub use crate::coordinator::{TrainReport, Trainer};
    pub use crate::data::{Augment, Batch, Loader, SynthDataset};
    #[cfg(feature = "pjrt")]
    pub use crate::runtime::Engine;
    pub use crate::runtime::{
        ApplyParams, BackendSpec, ComputeBackend, ComputeClient, ComputeService, Manifest,
        ReferenceBackend, StateRef,
    };
    pub use crate::sched::{BatchSchedule, LrSchedule, Phase};
    pub use crate::simnet::{Algo, ClusterModel};
    pub use crate::storage::{LocalDir, StorageBackend};
}
