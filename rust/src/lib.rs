//! # flash-sgd
//!
//! Reproduction of **"Massively Distributed SGD: ImageNet/ResNet-50
//! Training in a Flash"** (Mikami et al., Sony, 2018) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   2D-Torus / ring / hierarchical all-reduce over an in-memory rank mesh,
//!   batch-size control, LR/momentum schedules, LARS, data pipeline, and an
//!   ABCI-scale network simulator that regenerates the paper's tables.
//! * **Layer 2 (`python/compile/`)** — the ResNet model (BN without moving
//!   average) lowered once to HLO text via `jax.jit(...).lower(...)`.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for LARS and
//!   label-smoothed softmax cross-entropy, baked into the same artifacts.
//!
//! Python never runs at training time: `runtime::Engine` loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and the
//! coordinator drives everything from Rust worker threads.
//!
//! See `DESIGN.md` for the full inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod cluster;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod optim;
pub mod repro;
pub mod runtime;
pub mod sched;
pub mod simnet;
pub mod util;

/// Locate the AOT artifacts directory: `$FLASHSGD_ARTIFACTS`, then
/// `./artifacts`, then `<repo>/artifacts` (compile-time fallback so the
/// examples and benches work from any working directory).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("FLASHSGD_ARTIFACTS") {
        return dir.into();
    }
    let local = std::path::Path::new("artifacts");
    if local.join("manifest.json").exists() {
        return local.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::cluster::{best_grid, Grid, Placement};
    pub use crate::collectives::{
        Collective, HierarchicalAllReduce, Mesh, RingAllReduce, TorusAllReduce, Wire,
    };
    pub use crate::config::{paper_run, paper_runs, TrainConfig};
    pub use crate::coordinator::{TrainReport, Trainer};
    pub use crate::data::{Augment, Batch, Loader, SynthDataset};
    pub use crate::runtime::{Engine, Manifest};
    pub use crate::sched::{BatchSchedule, LrSchedule, Phase};
    pub use crate::simnet::{Algo, ClusterModel};
}
