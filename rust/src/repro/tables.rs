//! Regeneration of every table in the paper (DESIGN.md §5 experiment
//! index). Each function prints the paper's rows next to this system's
//! modelled/measured values; the benches in `rust/benches/` call these and
//! EXPERIMENTS.md records the outputs.

use std::fmt::Write as _;

use crate::cluster::{best_grid, TABLE4_GRIDS};
use crate::config::{paper_runs, LrConfig};
use crate::simnet::{
    Algo, ClusterModel, RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16,
};

fn torus_at(n: usize) -> Algo {
    let (x, y) = best_grid(n);
    Algo::Torus { x, y }
}

/// Table 1: training time and top-1 accuracy across the literature.
/// Static rows from the paper + this system's modelled "this work" row.
pub fn table1() -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Table 1: ImageNet/ResNet-50 training time and accuracy");
    let _ = writeln!(
        s,
        "{:<18} {:>8} {:>20} {:>12} {:>10}",
        "work", "batch", "processor", "time", "top-1"
    );
    let rows = [
        ("He et al.", "256", "Tesla P100 x8", "29 hours", "75.3%"),
        ("Goyal et al.", "8K", "Tesla P100 x256", "1 hour", "76.3%"),
        ("Smith et al.", "8K->16K", "full TPU Pod", "30 mins", "76.1%"),
        ("Akiba et al.", "32K", "Tesla P100 x1024", "15 mins", "74.9%"),
        ("Jia et al.", "64K", "Tesla P40 x2048", "6.6 mins", "75.8%"),
        ("Ying et al.", "32K", "TPU v3 x1024", "2.2 mins", "76.3%"),
        ("Ying et al.", "64K", "TPU v3 x1024", "1.8 mins", "75.2%"),
        ("This work (paper)", "54K", "Tesla V100 x3456", "2.0 mins", "75.29%"),
    ];
    for (w, b, p, t, a) in rows {
        let _ = writeln!(s, "{w:<18} {b:>8} {p:>20} {t:>12} {a:>10}");
    }
    let modelled = simulated_training_secs("exp2");
    let _ = writeln!(
        s,
        "{:<18} {:>8} {:>20} {:>11.1}s {:>10}",
        "This repo (model)", "54K", "simnet V100 x3456", modelled, "(twin run)"
    );
    s
}

/// Table 2: GPU scaling efficiency at ~1024 GPUs across the literature.
pub fn table2() -> String {
    let m = ClusterModel::abci_v100();
    let ours = 100.0
        * m.scaling_efficiency(
            torus_at,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        );
    let mut s = String::new();
    let _ = writeln!(s, "Table 2: GPU scaling efficiency, ImageNet/ResNet-50");
    let _ = writeln!(
        s,
        "{:<18} {:>20} {:>22} {:>12}",
        "work", "processor", "interconnect", "efficiency"
    );
    let rows = [
        ("Goyal et al.", "Tesla P100 x256", "50Gbit Ethernet", "~90%"),
        ("Akiba et al.", "Tesla P100 x1024", "Infiniband FDR", "80%"),
        ("Jia et al.", "Tesla P40 x1024", "100Gbit Ethernet", "87.9%"),
        ("This work (paper)", "Tesla V100 x1024", "Infiniband EDR x2", "84.75%"),
    ];
    for (w, p, i, e) in rows {
        let _ = writeln!(s, "{w:<18} {p:>20} {i:>22} {e:>12}");
    }
    let _ = writeln!(
        s,
        "{:<18} {:>20} {:>22} {:>11.2}%",
        "This repo (model)", "simnet V100 x1024", "alpha-beta IB EDR x2", ours
    );
    s
}

/// Table 3: the training configurations (presets echoed back).
pub fn table3() -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Table 3: training configurations");
    let _ = writeln!(
        s,
        "{:<10} {:>6} {:>4} {:>4}  batch phases (epoch: per-worker x workers = total)",
        "run", "#GPUs", "LS", "LR"
    );
    for r in paper_runs() {
        let lr = match r.lr {
            LrConfig::Reference => "-",
            LrConfig::A => "A",
            LrConfig::B => "B",
        };
        let phases: Vec<String> = r
            .schedule
            .phases()
            .iter()
            .map(|p| {
                format!(
                    "{}: {}x{}={}",
                    p.from_epoch,
                    p.per_worker,
                    p.workers,
                    p.total_batch()
                )
            })
            .collect();
        let _ = writeln!(
            s,
            "{:<10} {:>6} {:>4} {:>4}  [{}]",
            r.name,
            r.gpus_max,
            if r.label_smoothing > 0.0 { "yes" } else { "no" },
            lr,
            phases.join(", ")
        );
    }
    s
}

/// Table 4: 2D-torus grid dimensions per GPU count.
pub fn table4() -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Table 4: 2D-torus grid dimensions");
    let _ = writeln!(s, "{:>6} {:>9} {:>11} {:>11}", "#GPUs", "vertical", "horizontal", "p2p steps");
    for &(n, v, h) in TABLE4_GRIDS {
        let steps = 2 * (h - 1) + 2 * (v - 1);
        let _ = writeln!(s, "{n:>6} {v:>9} {h:>11} {steps:>11}");
    }
    s
}

/// Modelled wall-clock seconds for a paper run's full schedule: pure step
/// time over the batch schedule plus a fixed per-run overhead (startup,
/// validation, BN-stat finalisation) fitted on the headline Exp. 2 row
/// (122 s).
///
/// The Reference row is knowingly NOT reproduced by this model: its 505 s
/// implies ~228 img/s/GPU while Table 6 measures ~543 img/s/GPU on the same
/// hardware — the row ran "[10]'s training settings" on an older software
/// path. EXPERIMENTS.md §Table 5 discusses the discrepancy.
pub fn simulated_training_secs(run_name: &str) -> f64 {
    let runs = paper_runs();
    let run = runs.iter().find(|r| r.name == run_name).expect("run");
    let m = ClusterModel::abci_v100();
    let dataset = 1_281_167usize; // ImageNet train size

    let pure = |r: &crate::config::PaperRun| -> f64 {
        let mut secs = 0.0;
        for e in 0..r.schedule.total_epochs {
            let ph = r.schedule.at(e);
            let steps = dataset.div_ceil(ph.total_batch());
            let algo = torus_at(ph.workers);
            let st = m.step_time(
                algo,
                ph.workers,
                ph.per_worker,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
            );
            secs += steps as f64 * st.total_secs();
        }
        secs
    };

    // Fixed overhead fitted on the headline run (exp2 = 122 s).
    let exp2 = runs.iter().find(|r| r.name == "exp2").unwrap();
    let overhead = (122.0 - pure(exp2)).max(0.0);

    pure(run) + overhead
}

/// Table 5: accuracy and training time. Accuracy comes from the
/// reduced-scale twin runs (bench `table5_training`); time from the model.
pub fn table5() -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Table 5: validation accuracy and training time");
    let _ = writeln!(
        s,
        "{:<10} {:>6} {:>12} {:>10} {:>12} {:>14}",
        "run", "#GPUs", "batch", "paper acc", "paper time", "modelled time"
    );
    for r in paper_runs() {
        let modelled = simulated_training_secs(r.name);
        let batch = if r.schedule.min_total_batch() == r.schedule.max_total_batch() {
            format!("{}K", r.schedule.min_total_batch() / 1024)
        } else {
            format!(
                "{}K/{}K",
                r.schedule.min_total_batch() / 1024,
                r.schedule.max_total_batch() / 1024
            )
        };
        let _ = writeln!(
            s,
            "{:<10} {:>6} {:>12} {:>9.2}% {:>11.0}s {:>13.0}s",
            r.name, r.gpus_max, batch, r.paper_accuracy, r.paper_secs, modelled
        );
    }
    let _ = writeln!(
        s,
        "(accuracy reproduced at reduced scale by `cargo bench --bench table5_training`)"
    );
    s
}

/// Table 6: training throughput and scaling efficiency of the 2D-torus.
pub fn table6() -> String {
    let m = ClusterModel::abci_v100();
    let paper: &[(usize, f64, Option<f64>)] = &[
        (4, 2565.0, None),
        (1024, 556_522.0, Some(84.75)),
        (2048, 1_091_357.0, Some(83.10)),
        (3456, 1_641_853.0, Some(74.08)),
        (4096, 1_929_054.0, Some(73.44)),
    ];
    let mut s = String::new();
    let _ = writeln!(s, "Table 6: 2D-torus throughput and scaling efficiency (B=32/worker)");
    let _ = writeln!(
        s,
        "{:>6} {:>14} {:>11} {:>14} {:>11}",
        "#GPUs", "paper img/s", "paper eff", "model img/s", "model eff"
    );
    for &(n, p_thr, p_eff) in paper {
        let thr = m.throughput(
            torus_at(n),
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        );
        let eff = 100.0
            * m.scaling_efficiency(
                torus_at,
                n,
                32,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
            );
        let p_eff_s = p_eff.map_or("-".to_string(), |e| format!("{e:.2}%"));
        let eff_s = if n == 4 { "-".to_string() } else { format!("{eff:.2}%") };
        let _ = writeln!(s, "{n:>6} {p_thr:>14.0} {p_eff_s:>11} {thr:>14.0} {eff_s:>11}");
    }
    s
}

/// Figure 1: the 2D-torus topology (ASCII rendering of the ring structure).
pub fn figure1(x: usize, y: usize) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Figure 1: 2D-torus topology, {x} horizontal x {y} vertical");
    for row in 0..y {
        let mut line = String::new();
        for col in 0..x {
            let _ = write!(line, "G{:<3}", row * x + col);
            if col + 1 < x {
                line.push_str("— ");
            }
        }
        let _ = writeln!(s, "  {line} ⟲  (horizontal ring)");
        if row + 1 < y {
            let _ = writeln!(s, "  {}", "|    ".repeat(x));
        }
    }
    let _ = writeln!(s, "  (columns wrap vertically: each column is a ring ⟲)");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        for t in [table1(), table2(), table3(), table4(), table5(), table6()] {
            assert!(t.lines().count() >= 5, "{t}");
        }
    }

    #[test]
    fn table6_model_matches_paper_shape() {
        let t = table6();
        assert!(t.contains("84.75%"));
        // modelled efficiencies present for all scales
        assert!(t.lines().count() == 7);
    }

    #[test]
    fn simulated_times_ordered_like_paper() {
        // exp2 anchors the overhead fit at exactly the paper's 122 s.
        let exp2 = simulated_training_secs("exp2");
        assert!((exp2 - 122.0).abs() < 0.5, "exp2 fitted: {exp2}");
        // exp3 (64K after epoch 30) is a touch faster, like the paper
        // (115 s); shape within 20%.
        let exp3 = simulated_training_secs("exp3");
        assert!(exp3 < exp2, "exp3 {exp3} !< exp2 {exp2}");
        assert!((exp3 - 115.0).abs() / 115.0 < 0.20, "exp3 modelled {exp3}");
        // exp4 (129 s) within 35%.
        let exp4 = simulated_training_secs("exp4");
        assert!((exp4 - 129.0).abs() / 129.0 < 0.35, "exp4 modelled {exp4}");
        // the 1024-GPU reference is far slower than the 3456-GPU headline
        // (paper: 505 s; our model reproduces the optimized stack only —
        // see doc comment).
        let reference = simulated_training_secs("reference");
        assert!(reference > 1.5 * exp2, "ref {reference} vs exp2 {exp2}");
    }

    #[test]
    fn figure1_renders_grid() {
        let f = figure1(4, 2);
        assert!(f.contains("G0"));
        assert!(f.contains("G7"));
    }
}
