//! Paper-artifact regeneration: one function per table/figure (DESIGN.md §5).

pub mod tables;

pub use tables::{figure1, simulated_training_secs, table1, table2, table3, table4, table5, table6};
