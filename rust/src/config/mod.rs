//! Run configuration: the knobs of a training run (reduced-scale twin or
//! paper-scale simulation), loadable from TOML files and from presets.

pub mod presets;

pub use presets::{paper_run, paper_runs, LrConfig, PaperRun};

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::collectives::transport::chaos::ChaosConfig;
use crate::collectives::transport::BackoffConfig;
use crate::sched::{BatchSchedule, LrSchedule, Phase};
use crate::util::toml::Doc;

/// How a deterministically injected fault manifests in the afflicted
/// worker (the in-process stand-in for a GPU/node dying mid-run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker returns an error from its step loop.
    Error,
    /// The worker thread panics outright.
    Panic,
    /// The worker goes silent for `millis` (then errors out) — exercises
    /// heartbeat-timeout detection rather than fast error propagation.
    Hang { millis: u64 },
    /// The worker's local step loss is poisoned to NaN — exercises the
    /// numeric health guard: every rank sees the NaN through the FP32
    /// loss reduction and the run fails with a typed
    /// [`crate::coordinator::NonFiniteError`] naming rank and step,
    /// instead of silently training on garbage.
    NanLoss,
    /// The worker sleeps `millis` extra on **every** step from `step`
    /// onward (chronic, unlike the one-shot kinds above) — the seeded
    /// straggler: numerics are untouched, only the clock suffers.
    /// Exercises slow-rank telemetry and the `[fault.straggler]` policy.
    Slow { millis: u64 },
}

/// A deterministic fault injection: rank `rank` dies at global step
/// `step`, on the first `attempts` attempts of the afflicted phase (so a
/// recovered phase does not re-trigger it forever).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub rank: usize,
    /// Global optimizer step at which the fault fires.
    pub step: usize,
    pub kind: FaultKind,
    /// Number of phase attempts on which to fire (1 = first attempt only).
    pub attempts: usize,
}

impl InjectedFault {
    /// Kill `rank` with an error at global `step` (first attempt only).
    pub fn error_at(rank: usize, step: usize) -> Self {
        Self { rank, step, kind: FaultKind::Error, attempts: 1 }
    }

    pub fn panic_at(rank: usize, step: usize) -> Self {
        Self { rank, step, kind: FaultKind::Panic, attempts: 1 }
    }

    pub fn hang_at(rank: usize, step: usize, millis: u64) -> Self {
        Self { rank, step, kind: FaultKind::Hang { millis }, attempts: 1 }
    }

    /// Poison `rank`'s local loss with NaN at global `step` (first
    /// attempt only) — the numeric-health-guard regression hook.
    pub fn nan_at(rank: usize, step: usize) -> Self {
        Self { rank, step, kind: FaultKind::NanLoss, attempts: 1 }
    }

    /// Make `rank` chronically slow: `millis` of extra sleep on every step
    /// from global `step` onward (first attempt only).
    pub fn slow_at(rank: usize, step: usize, millis: u64) -> Self {
        Self { rank, step, kind: FaultKind::Slow { millis }, attempts: 1 }
    }

    /// Does this injection fire *fatally* for (`attempt`, `rank`,
    /// `global_step`)? Always false for [`FaultKind::Slow`] — slowness is
    /// chronic and non-fatal; see [`Self::slow_millis`].
    pub fn fires(&self, attempt: usize, rank: usize, global_step: usize) -> bool {
        !matches!(self.kind, FaultKind::Slow { .. })
            && attempt < self.attempts
            && rank == self.rank
            && global_step == self.step
    }

    /// Extra per-step sleep for (`attempt`, `rank`, `global_step`), if
    /// this is a [`FaultKind::Slow`] injection in effect: unlike
    /// [`Self::fires`] the condition is `global_step >= step` — a
    /// straggler stays slow, it does not stumble once.
    pub fn slow_millis(&self, attempt: usize, rank: usize, global_step: usize) -> Option<u64> {
        match self.kind {
            FaultKind::Slow { millis }
                if attempt < self.attempts && rank == self.rank && global_step >= self.step =>
            {
                Some(millis)
            }
            _ => None,
        }
    }
}

/// What to do about a rank whose step-time EWMA is chronically above
/// `slow_factor ×` the cluster median (see [`StragglerConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerPolicy {
    /// Telemetry only: score and report stragglers, never act.
    Observe,
    /// Drain the straggler at the next phase boundary via the elastic
    /// re-plan (constant global batch, no mid-collective abort); it may
    /// rejoin through the join door once healthy.
    Demote,
    /// Remove the straggler permanently: no rejoin window is held for it.
    Evict,
}

/// `[fault.straggler]` — slow-rank detection and mitigation. A rank is a
/// *straggler* when its local-work EWMA (compute + apply, comm excluded —
/// in a synchronous collective everyone's total step time converges to
/// the slowest rank's, so only local work identifies the culprit) exceeds
/// `slow_factor ×` the median of live ranks' EWMAs for `grace` of
/// wall-clock, with at least `min_samples` steps observed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerConfig {
    /// EWMA threshold relative to the cluster median (> 1).
    pub slow_factor: f64,
    /// Steps a rank must have reported before it can be judged.
    pub min_samples: u64,
    /// How long the rank must stay over threshold before the policy acts
    /// (one slow step is noise; a straggler is a *trend*).
    pub grace: Duration,
    /// What to do once a straggler is confirmed.
    pub policy: StragglerPolicy,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        Self {
            slow_factor: 2.0,
            min_samples: 8,
            grace: Duration::ZERO,
            policy: StragglerPolicy::Observe,
        }
    }
}

/// Fault-tolerance knobs: heartbeat failure detection + elastic mid-phase
/// recovery (ROADMAP item 2). With `enabled = false` the trainer behaves
/// exactly as before this subsystem existed: no monitor thread, no recv
/// deadline, no per-phase state retention — and any rank failure aborts
/// the run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    pub enabled: bool,
    /// How often the coordinator's monitor scans the heartbeat table.
    pub heartbeat_interval: Duration,
    /// A rank whose heartbeat is older than this is declared dead. Must
    /// comfortably exceed the longest compute gap between collectives
    /// (rank 0's in-phase eval is the usual worst case).
    pub rank_timeout: Duration,
    /// Total phase restarts allowed across the run before a death becomes
    /// fatal.
    pub max_restarts: usize,
    /// After a phase fails with dead ranks, how long the coordinator holds
    /// the re-plan open for the casualties to rejoin (`flashsgd worker
    /// --join` again). Zero = re-plan immediately on the survivors; a
    /// rejoiner then has to wait for the *next* boundary. A non-zero grace
    /// makes a kill-and-restart deterministic: the replacement is admitted
    /// before the re-plan, so the replay runs at full width and the run
    /// stays byte-identical to an undisturbed one.
    pub rejoin_grace: Duration,
    /// How long an orphaned worker — one whose *control* link to the
    /// coordinator died — keeps itself alive and re-dials the join
    /// address, instead of exiting. Zero (default) = the pre-durability
    /// behaviour: losing the coordinator is fatal to the worker. Set it
    /// comfortably above the coordinator's expected restart +
    /// `--resume` time so a SIGKILL'd coordinator finds its full worker
    /// set waiting at the join door.
    pub coordinator_grace: Duration,
    /// Seeded network-chaos injection (`[fault.chaos]`); disabled by
    /// default, in which case the transport path is exactly the
    /// chaos-free code.
    pub chaos: ChaosConfig,
    /// Slow-rank detection + mitigation (`[fault.straggler]`). Defaults
    /// to `Observe` with zero grace — pure telemetry, no behaviour change.
    pub straggler: StragglerConfig,
    /// Deterministic fault injection (tests / chaos runs); `None` in
    /// production configs.
    pub inject: Option<InjectedFault>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            heartbeat_interval: Duration::from_millis(200),
            rank_timeout: Duration::from_secs(30),
            max_restarts: 1,
            rejoin_grace: Duration::ZERO,
            coordinator_grace: Duration::ZERO,
            chaos: ChaosConfig::default(),
            straggler: StragglerConfig::default(),
            inject: None,
        }
    }
}

impl FaultConfig {
    /// Fault tolerance fully off: any rank failure is fatal, exactly the
    /// pre-fault-tolerance behaviour.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// Durable-run knobs (`[checkpoint]` table): the write-ahead run journal
/// and periodic async snapshots (ROADMAP item 4's durability slice).
///
/// With `dir` empty (the default) nothing here runs and the trainer
/// behaves exactly as before durability existed: checkpoints are only
/// written on demand via `--save`. With `dir` set, the coordinator keeps
/// `journal.wal` there, writes `snap-<step>.ckpt` phase-boundary
/// snapshots through the [`crate::storage::StorageBackend`] on a
/// background thread, and `--resume <dir>` continues the run from the
/// newest valid snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// Snapshot cadence in global steps: at a phase boundary, snapshot
    /// when at least this many steps passed since the last snapshot.
    /// 0 = snapshot at every phase boundary.
    pub every_steps: usize,
    /// Snapshot generations retained; older ones are garbage-collected
    /// after each write. At least 1 (2+ recommended — the corrupt-newest
    /// fallback needs a previous generation to fall back to).
    pub keep_last: usize,
    /// Durable directory (journal + snapshots). Empty = durability off.
    pub dir: String,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        Self {
            every_steps: 0,
            keep_last: 3,
            dir: String::new(),
        }
    }
}

impl CheckpointConfig {
    /// Is the durability layer on for this run?
    pub fn enabled(&self) -> bool {
        !self.dir.is_empty()
    }
}

/// Transport selection + process-mode addresses (`[transport]` table).
///
/// `mode` picks the channel under `train`: `"memory"` (default — the
/// in-process mesh, bit-identical to the pre-transport-layer behaviour)
/// or `"tcp"` (the same ranks over loopback TCP sockets, exercising the
/// frame codec and reader threads in-process). The `coordinator` /
/// `worker` subcommands always speak TCP; `bind` is the coordinator's
/// control-socket address (workers join by dialing it), `http` an
/// optional plain-HTTP status/metrics listener (empty = off), and
/// `max_frame_bytes` the frame-size cap both sides enforce on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportConfig {
    pub mode: String,
    /// Coordinator control-socket bind / join address.
    pub bind: String,
    /// HTTP status endpoint bind address ("" = disabled).
    pub http: String,
    /// Hard cap on one framed message (header + payload).
    pub max_frame_bytes: usize,
    /// Jittered exponential backoff for dials and re-dials (replaces the
    /// old fixed `DIAL_RETRY`/`JOIN_RETRY` constants): `retry_base_ms`,
    /// `retry_max_ms`, `retry_attempts`, `retry_jitter` in TOML.
    pub backoff: BackoffConfig,
    /// How many times a transient read/write error on an *established*
    /// data connection may be healed by re-dial + seq-fenced resync before
    /// the peer is declared dead. 0 (default) = the pre-reconnect
    /// behaviour: any socket error on an established link kills the peer.
    pub reconnect_attempts: u32,
    /// How many recently sent frames each link retains for replay after a
    /// reconnect. A gap wider than this window makes the link unhealable
    /// (the peer is declared dead as before).
    pub resync_window: usize,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            mode: "memory".into(),
            bind: "127.0.0.1:7070".into(),
            http: String::new(),
            max_frame_bytes: crate::collectives::transport::frame::DEFAULT_MAX_FRAME_BYTES,
            backoff: BackoffConfig::default(),
            reconnect_attempts: 0,
            resync_window: 64,
        }
    }
}

/// Everything the Trainer needs for one run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub name: String,
    /// Manifest architecture ("tiny" on the default reference backend;
    /// whatever the artifact manifest provides under `--features pjrt`).
    pub arch: String,
    /// Collective spec ("torus" | "torus:<X>x<Y>" | "ring" | "hierarchical:<g>").
    pub collective: String,
    /// Gradient wire precision ("fp16" per the paper, or "fp32").
    pub grad_wire: String,
    pub label_smoothing: f32,
    pub lr: LrSchedule,
    pub batch: BatchSchedule,
    pub weight_decay: f32,
    pub seed: u64,
    /// Hard cap on optimizer steps (0 = run the schedule's epochs).
    pub max_steps: usize,
    /// Evaluate every N global optimizer steps — a *step interval*, not a
    /// phase-boundary flag (0 = only the single evaluation at the end of
    /// the run; the final eval always happens and is never duplicated when
    /// the interval lands on the last step).
    pub eval_every: usize,
    /// Number of validation batches per evaluation.
    pub eval_batches: usize,
    /// Synthetic dataset size (train split).
    pub train_size: usize,
    /// Width of the compute pool: lanes (backend threads) executing
    /// grad/apply concurrently. 0 = auto, one lane per rank of the widest
    /// phase; 1 = fully serialized (the pre-pool behaviour, bit-identical
    /// results either way).
    pub compute_lanes: usize,
    /// Target bytes (f32 accumulator: 4 bytes/element, regardless of the
    /// wire dtype) per gradient bucket of the backward-overlapped
    /// reduction. Buckets are tensor-aligned and built in reverse layer
    /// order, so bucket *k* all-reduces while backprop still produces
    /// bucket *k+1*. `0` = a single bucket: the fully serial
    /// grad→reduce→apply schedule, bit-identical to the pre-pipeline
    /// behaviour. The default (8 KiB) yields ~6–7 buckets on the tiny
    /// arch.
    pub bucket_bytes: usize,
    /// Fault tolerance: heartbeat detection + elastic mid-phase recovery.
    pub fault: FaultConfig,
    /// Transport selection (in-memory vs TCP) and process-mode addresses.
    pub transport: TransportConfig,
    /// Durability: run journal + periodic async snapshots (`[checkpoint]`).
    pub checkpoint: CheckpointConfig,
}

/// Default gradient-bucket target: ~6–7 tensor-aligned buckets over the
/// tiny arch's ~123 KiB gradient, enough for the reduction of early
/// buckets to hide behind the remaining backward pass.
pub const DEFAULT_BUCKET_BYTES: usize = 8 * 1024;

impl TrainConfig {
    /// Quick default: tiny arch, 4 workers in a 2×2 torus.
    pub fn quickstart() -> Self {
        Self {
            name: "quickstart".into(),
            arch: "tiny".into(),
            collective: "torus:2x2".into(),
            grad_wire: "fp16".into(),
            label_smoothing: 0.1,
            lr: LrSchedule::Const { lr: 1.0, momentum: 0.9 },
            batch: BatchSchedule::constant(8, 4, 2),
            weight_decay: 5e-5,
            seed: 42,
            max_steps: 30,
            eval_every: 0,
            eval_batches: 4,
            train_size: 4096,
            compute_lanes: 0,
            bucket_bytes: DEFAULT_BUCKET_BYTES,
            fault: FaultConfig::default(),
            transport: TransportConfig::default(),
            checkpoint: CheckpointConfig::default(),
        }
    }

    /// Reduced-scale twin of a paper run (DESIGN.md §4): same stabilisers,
    /// schedule structure and wire precision; worker count scaled to
    /// `ranks`, LR linearly rescaled to the twin's global batch.
    pub fn twin_of(paper: &PaperRun, ranks: usize, arch: &str, epochs: u32) -> Self {
        let mut batch = paper.schedule.scaled_to(ranks);
        batch.total_epochs = epochs;
        // Keep the paper's relative phase boundaries under the shorter run.
        let scale = epochs as f64 / paper.schedule.total_epochs as f64;
        let phases: Vec<Phase> = batch
            .phases()
            .iter()
            .map(|p| Phase {
                from_epoch: (p.from_epoch as f64 * scale).round() as u32,
                ..*p
            })
            .collect();
        // Dedup boundaries that collapsed onto each other.
        let mut dedup: Vec<Phase> = Vec::new();
        for p in phases {
            if dedup.last().map(|l| l.from_epoch) == Some(p.from_epoch) {
                *dedup.last_mut().unwrap() = p;
            } else {
                dedup.push(p);
            }
        }
        let batch = BatchSchedule::new(dedup, epochs);

        // Linear LR transfer from the paper's batch to the twin's.
        let paper_batch = paper.schedule.at(0).total_batch();
        let twin_batch = batch.at(0).total_batch();
        let lr = match paper.lr.schedule() {
            LrSchedule::ConfigA { base, initial, warmup_epochs, total_epochs } => {
                LrSchedule::ConfigA {
                    base: LrSchedule::scale_lr(base, paper_batch, twin_batch),
                    initial,
                    warmup_epochs: warmup_epochs * scale,
                    total_epochs: total_epochs * scale,
                }
            }
            LrSchedule::ConfigB {
                warmup_epochs,
                warmup_start,
                base_low,
                base_high,
                switch_epoch,
                total_epochs,
            } => LrSchedule::ConfigB {
                warmup_epochs: warmup_epochs * scale,
                warmup_start: LrSchedule::scale_lr(warmup_start, paper_batch, twin_batch),
                base_low: LrSchedule::scale_lr(base_low, paper_batch, twin_batch),
                base_high: LrSchedule::scale_lr(base_high, paper_batch, twin_batch),
                switch_epoch: switch_epoch * scale,
                total_epochs: total_epochs * scale,
            },
            other => other,
        };

        Self {
            name: format!("{}-twin", paper.name),
            arch: arch.to_string(),
            collective: "torus".into(),
            grad_wire: "fp16".into(),
            label_smoothing: paper.label_smoothing,
            lr,
            batch,
            weight_decay: 5e-5,
            seed: 42,
            max_steps: 0,
            eval_every: 0,
            eval_batches: 8,
            train_size: 4096,
            compute_lanes: 0,
            bucket_bytes: DEFAULT_BUCKET_BYTES,
            fault: FaultConfig::default(),
            transport: TransportConfig::default(),
            checkpoint: CheckpointConfig::default(),
        }
    }

    /// Parse from a TOML document (see `configs/*.toml` for the format).
    pub fn from_toml(doc: &Doc) -> Result<Self> {
        let name = doc.str_or("name", "run")?;
        let arch = doc.str_or("arch", "tiny")?;
        let collective = doc.str_or("collective", "torus")?;
        let grad_wire = doc.str_or("grad_wire", "fp16")?;
        if grad_wire != "fp16" && grad_wire != "fp32" {
            bail!("grad_wire must be fp16 or fp32, got {grad_wire:?}");
        }
        let label_smoothing = doc.f64_or("label_smoothing", 0.1)? as f32;
        let weight_decay = doc.f64_or("weight_decay", 5e-5)? as f32;
        let seed = doc.usize_or("seed", 42)? as u64;
        let max_steps = doc.usize_or("max_steps", 0)?;
        let eval_every = doc.usize_or("eval_every", 0)?;
        let eval_batches = doc.usize_or("eval_batches", 8)?;
        let train_size = doc.usize_or("train_size", 4096)?;
        let compute_lanes = doc.usize_or("compute_lanes", 0)?;
        let bucket_bytes = doc.usize_or("bucket_bytes", DEFAULT_BUCKET_BYTES)?;
        let total_epochs = doc.usize_or("epochs", 2)? as u32;

        // Fault tolerance ([fault] table; all optional).
        let fd = FaultConfig::default();
        let fault = FaultConfig {
            enabled: doc.bool_or("fault.enabled", fd.enabled)?,
            heartbeat_interval: Duration::from_millis(doc.usize_or(
                "fault.heartbeat_interval_ms",
                fd.heartbeat_interval.as_millis() as usize,
            )? as u64),
            rank_timeout: Duration::from_millis(doc.usize_or(
                "fault.rank_timeout_ms",
                fd.rank_timeout.as_millis() as usize,
            )? as u64),
            max_restarts: doc.usize_or("fault.max_restarts", fd.max_restarts)?,
            rejoin_grace: Duration::from_millis(doc.usize_or(
                "fault.rejoin_grace_ms",
                fd.rejoin_grace.as_millis() as usize,
            )? as u64),
            coordinator_grace: Duration::from_millis(doc.usize_or(
                "fault.coordinator_grace_ms",
                fd.coordinator_grace.as_millis() as usize,
            )? as u64),
            chaos: ChaosConfig {
                enabled: doc.bool_or("fault.chaos.enabled", fd.chaos.enabled)?,
                seed: doc.usize_or("fault.chaos.seed", fd.chaos.seed as usize)? as u64,
                delay_prob: doc.f64_or("fault.chaos.delay_prob", fd.chaos.delay_prob)?,
                delay_us_max: doc
                    .usize_or("fault.chaos.delay_us_max", fd.chaos.delay_us_max as usize)?
                    as u64,
                drop_prob: doc.f64_or("fault.chaos.drop_prob", fd.chaos.drop_prob)?,
                drop_delay_us: doc
                    .usize_or("fault.chaos.drop_delay_us", fd.chaos.drop_delay_us as usize)?
                    as u64,
                dup_prob: doc.f64_or("fault.chaos.dup_prob", fd.chaos.dup_prob)?,
                reorder_prob: doc.f64_or("fault.chaos.reorder_prob", fd.chaos.reorder_prob)?,
                slow_prob: doc.f64_or("fault.chaos.slow_prob", fd.chaos.slow_prob)?,
                slow_factor: doc.f64_or("fault.chaos.slow_factor", fd.chaos.slow_factor)?,
            },
            straggler: StragglerConfig {
                slow_factor: doc
                    .f64_or("fault.straggler.slow_factor", fd.straggler.slow_factor)?,
                min_samples: doc.usize_or(
                    "fault.straggler.min_samples",
                    fd.straggler.min_samples as usize,
                )? as u64,
                grace: Duration::from_millis(doc.usize_or(
                    "fault.straggler.grace_ms",
                    fd.straggler.grace.as_millis() as usize,
                )? as u64),
                policy: match doc.str_or("fault.straggler.policy", "observe")?.as_str() {
                    "observe" => StragglerPolicy::Observe,
                    "demote" => StragglerPolicy::Demote,
                    "evict" => StragglerPolicy::Evict,
                    p => bail!(
                        "fault.straggler.policy must be observe | demote | evict, got {p:?}"
                    ),
                },
            },
            // Deterministic injection from TOML (CI / chaos configs): flat
            // `inject_*` keys, present only when `inject_kind` is set.
            inject: match doc.get("fault.inject_kind") {
                None => None,
                Some(v) => {
                    let rank = doc.usize_or("fault.inject_rank", 0)?;
                    let step = doc.usize_or("fault.inject_step", 0)?;
                    let millis = doc.usize_or("fault.inject_millis", 0)? as u64;
                    let attempts = doc.usize_or("fault.inject_attempts", 1)?;
                    let kind = match v.as_str()? {
                        "error" => FaultKind::Error,
                        "panic" => FaultKind::Panic,
                        "hang" => FaultKind::Hang { millis },
                        "nan" => FaultKind::NanLoss,
                        "slow" => FaultKind::Slow { millis },
                        k => bail!(
                            "fault.inject_kind must be error | panic | hang | nan | slow, \
                             got {k:?}"
                        ),
                    };
                    Some(InjectedFault { rank, step, kind, attempts })
                }
            },
        };
        if fault.enabled && fault.rank_timeout.is_zero() {
            bail!("fault.rank_timeout_ms must be > 0 when fault tolerance is enabled");
        }
        for (key, p) in [
            ("fault.chaos.delay_prob", fault.chaos.delay_prob),
            ("fault.chaos.drop_prob", fault.chaos.drop_prob),
            ("fault.chaos.dup_prob", fault.chaos.dup_prob),
            ("fault.chaos.reorder_prob", fault.chaos.reorder_prob),
            ("fault.chaos.slow_prob", fault.chaos.slow_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("{key} must be a probability in [0, 1], got {p}");
            }
        }
        if fault.chaos.slow_factor < 1.0 {
            bail!(
                "fault.chaos.slow_factor must be >= 1, got {}",
                fault.chaos.slow_factor
            );
        }
        if fault.straggler.slow_factor <= 1.0 {
            bail!(
                "fault.straggler.slow_factor must be > 1 (a threshold at or below the \
                 median flags half the cluster), got {}",
                fault.straggler.slow_factor
            );
        }
        if fault.straggler.min_samples == 0 {
            bail!("fault.straggler.min_samples must be >= 1");
        }

        // Transport ([transport] table; all optional).
        let td = TransportConfig::default();
        let transport = TransportConfig {
            mode: doc.str_or("transport.mode", &td.mode)?,
            bind: doc.str_or("transport.bind", &td.bind)?,
            http: doc.str_or("transport.http", &td.http)?,
            max_frame_bytes: doc.usize_or("transport.max_frame_bytes", td.max_frame_bytes)?,
            backoff: BackoffConfig {
                base: Duration::from_millis(doc.usize_or(
                    "transport.retry_base_ms",
                    td.backoff.base.as_millis() as usize,
                )? as u64),
                max: Duration::from_millis(doc.usize_or(
                    "transport.retry_max_ms",
                    td.backoff.max.as_millis() as usize,
                )? as u64),
                attempts: doc.usize_or("transport.retry_attempts", td.backoff.attempts as usize)?
                    as u32,
                jitter: doc.f64_or("transport.retry_jitter", td.backoff.jitter)?,
            },
            reconnect_attempts: doc
                .usize_or("transport.reconnect_attempts", td.reconnect_attempts as usize)?
                as u32,
            resync_window: doc.usize_or("transport.resync_window", td.resync_window)?,
        };
        if transport.mode != "memory" && transport.mode != "tcp" {
            bail!("transport.mode must be \"memory\" or \"tcp\", got {:?}", transport.mode);
        }
        if transport.max_frame_bytes < 64 {
            bail!("transport.max_frame_bytes of {} cannot fit a frame", transport.max_frame_bytes);
        }
        if transport.backoff.base.is_zero() || transport.backoff.max < transport.backoff.base {
            bail!(
                "transport retry backoff needs 0 < retry_base_ms <= retry_max_ms, got {:?}..{:?}",
                transport.backoff.base,
                transport.backoff.max
            );
        }
        if transport.backoff.attempts == 0 {
            bail!("transport.retry_attempts must be >= 1");
        }
        if !(0.0..=1.0).contains(&transport.backoff.jitter) {
            bail!("transport.retry_jitter must be in [0, 1], got {}", transport.backoff.jitter);
        }
        if transport.reconnect_attempts > 0 && transport.resync_window == 0 {
            bail!("transport.resync_window must be >= 1 when reconnect_attempts > 0");
        }

        // Durability ([checkpoint] table; all optional, off unless `dir`).
        let cd = CheckpointConfig::default();
        let checkpoint = CheckpointConfig {
            every_steps: doc.usize_or("checkpoint.every_steps", cd.every_steps)?,
            keep_last: doc.usize_or("checkpoint.keep_last", cd.keep_last)?,
            dir: doc.str_or("checkpoint.dir", &cd.dir)?,
        };
        if checkpoint.enabled() && checkpoint.keep_last == 0 {
            bail!("checkpoint.keep_last must be >= 1 when checkpoint.dir is set");
        }

        // LR schedule.
        let lr = match doc.str_or("lr.kind", "const")?.as_str() {
            "const" => LrSchedule::Const {
                lr: doc.f64_or("lr.value", 1.0)?,
                momentum: doc.f64_or("lr.momentum", 0.9)?,
            },
            "config_a" => LrSchedule::ConfigA {
                base: doc.f64_or("lr.base", 34.0)?,
                initial: doc.f64_or("lr.initial", 1e-5)?,
                warmup_epochs: doc.f64_or("lr.warmup_epochs", 34.0)?,
                total_epochs: doc.f64_or("lr.total_epochs", 90.0)?,
            },
            "config_b" => LrSchedule::ConfigB {
                warmup_epochs: doc.f64_or("lr.warmup_epochs", 5.0)?,
                warmup_start: doc.f64_or("lr.warmup_start", 0.2)?,
                base_low: doc.f64_or("lr.base_low", 29.0)?,
                base_high: doc.f64_or("lr.base_high", 50.0)?,
                switch_epoch: doc.f64_or("lr.switch_epoch", 30.0)?,
                total_epochs: doc.f64_or("lr.total_epochs", 90.0)?,
            },
            k => bail!("unknown lr.kind {k:?}"),
        };

        // Batch schedule: either flat keys or phase arrays.
        let batch = if let Some(v) = doc.get("batch.phases") {
            let mut phases = Vec::new();
            for (i, item) in v.as_arr()?.iter().enumerate() {
                let row = item.as_arr().with_context(|| format!("phase {i}"))?;
                if row.len() != 3 {
                    bail!("batch.phases[{i}] must be [from_epoch, per_worker, workers]");
                }
                phases.push(Phase {
                    from_epoch: row[0].as_usize()? as u32,
                    per_worker: row[1].as_usize()?,
                    workers: row[2].as_usize()?,
                });
            }
            BatchSchedule::new(phases, total_epochs)
        } else {
            BatchSchedule::constant(
                doc.usize_or("batch.per_worker", 8)?,
                doc.usize_or("batch.workers", 4)?,
                total_epochs,
            )
        };

        Ok(Self {
            name,
            arch,
            collective,
            grad_wire,
            label_smoothing,
            lr,
            batch,
            weight_decay,
            seed,
            max_steps,
            eval_every,
            eval_batches,
            train_size,
            compute_lanes,
            bucket_bytes,
            fault,
            transport,
            checkpoint,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_is_consistent() {
        let c = TrainConfig::quickstart();
        assert_eq!(c.batch.max_workers(), 4);
        assert_eq!(c.arch, "tiny");
    }

    #[test]
    fn twin_preserves_stabilisers_and_structure() {
        let paper = paper_run("exp4").unwrap();
        let twin = TrainConfig::twin_of(&paper, 8, "tiny", 6);
        assert_eq!(twin.label_smoothing, 0.1);
        assert_eq!(twin.batch.max_workers(), 8);
        assert_eq!(twin.batch.total_epochs, 6);
        // 4 phases may dedup if boundaries collapse at 6 epochs
        assert!(twin.batch.phases().len() >= 2);
        // per-worker batches survive
        assert_eq!(twin.batch.at(0).per_worker, 16);
    }

    #[test]
    fn twin_lr_is_rescaled_down() {
        let paper = paper_run("exp2").unwrap();
        let twin = TrainConfig::twin_of(&paper, 8, "tiny", 6);
        match twin.lr {
            LrSchedule::ConfigB { base_low, .. } => {
                assert!(base_low < 1.0, "54K-batch LR 29 must shrink, got {base_low}");
            }
            ref other => panic!("expected ConfigB, got {other:?}"),
        }
    }

    #[test]
    fn toml_round_trip() {
        let doc = Doc::parse(
            r#"
name = "t"
arch = "tiny"
collective = "torus:2x2"
epochs = 3
[lr]
kind = "config_b"
base_low = 1.5
[batch]
phases = [[0, 8, 4], [2, 16, 4]]
"#,
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.name, "t");
        assert_eq!(c.batch.phases().len(), 2);
        assert_eq!(c.batch.at(2).per_worker, 16);
        match c.lr {
            LrSchedule::ConfigB { base_low, .. } => assert_eq!(base_low, 1.5),
            _ => panic!(),
        }
    }

    #[test]
    fn bucket_bytes_defaults_and_parses() {
        assert_eq!(TrainConfig::quickstart().bucket_bytes, DEFAULT_BUCKET_BYTES);
        let doc = Doc::parse("bucket_bytes = 0\n").unwrap();
        assert_eq!(TrainConfig::from_toml(&doc).unwrap().bucket_bytes, 0);
        let doc = Doc::parse("bucket_bytes = 4096\n").unwrap();
        assert_eq!(TrainConfig::from_toml(&doc).unwrap().bucket_bytes, 4096);
    }

    #[test]
    fn fault_config_defaults_and_parses() {
        let c = TrainConfig::quickstart();
        assert!(c.fault.enabled);
        assert_eq!(c.fault.max_restarts, 1);
        assert!(c.fault.inject.is_none());

        let doc = Doc::parse(
            "[fault]\nenabled = false\nheartbeat_interval_ms = 50\n\
             rank_timeout_ms = 750\nmax_restarts = 3\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert!(!c.fault.enabled);
        assert_eq!(c.fault.heartbeat_interval, Duration::from_millis(50));
        assert_eq!(c.fault.rank_timeout, Duration::from_millis(750));
        assert_eq!(c.fault.max_restarts, 3);

        // zero timeout with fault tolerance on is a config error
        let doc = Doc::parse("[fault]\nrank_timeout_ms = 0\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        // ...but fine when the subsystem is off
        let doc = Doc::parse("[fault]\nenabled = false\nrank_timeout_ms = 0\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_ok());
    }

    #[test]
    fn injected_fault_gating() {
        let inj = InjectedFault::error_at(2, 7);
        assert!(inj.fires(0, 2, 7));
        assert!(!inj.fires(1, 2, 7), "attempt 1 must not re-fire");
        assert!(!inj.fires(0, 1, 7));
        assert!(!inj.fires(0, 2, 8));
        let twice = InjectedFault { attempts: 2, ..inj };
        assert!(twice.fires(1, 2, 7));
        assert!(!twice.fires(2, 2, 7));
    }

    #[test]
    fn slow_injection_is_chronic_and_never_fatal() {
        let inj = InjectedFault::slow_at(1, 5, 40);
        // never a fatal fault, at any step
        assert!(!inj.fires(0, 1, 5));
        assert!(!inj.fires(0, 1, 50));
        // chronic from `step` onward, on the afflicted rank + attempt only
        assert_eq!(inj.slow_millis(0, 1, 4), None, "not before its step");
        assert_eq!(inj.slow_millis(0, 1, 5), Some(40));
        assert_eq!(inj.slow_millis(0, 1, 99), Some(40), "stays slow");
        assert_eq!(inj.slow_millis(0, 0, 9), None, "wrong rank");
        assert_eq!(inj.slow_millis(1, 1, 9), None, "attempt exhausted");
        // one-shot kinds never report slowness
        assert_eq!(InjectedFault::error_at(1, 5).slow_millis(0, 1, 5), None);
    }

    #[test]
    fn straggler_config_defaults_and_parses() {
        let c = TrainConfig::quickstart();
        assert_eq!(c.fault.straggler, StragglerConfig::default());
        assert_eq!(c.fault.straggler.policy, StragglerPolicy::Observe);
        assert!(c.fault.inject.is_none());

        let doc = Doc::parse(
            "[fault.straggler]\npolicy = \"demote\"\nslow_factor = 3.0\n\
             min_samples = 4\ngrace_ms = 250\n",
        )
        .unwrap();
        let s = TrainConfig::from_toml(&doc).unwrap().fault.straggler;
        assert_eq!(s.policy, StragglerPolicy::Demote);
        assert_eq!(s.slow_factor, 3.0);
        assert_eq!(s.min_samples, 4);
        assert_eq!(s.grace, Duration::from_millis(250));

        // degenerate thresholds and unknown policies are config errors
        for bad in [
            "[fault.straggler]\npolicy = \"maim\"\n",
            "[fault.straggler]\nslow_factor = 1.0\n",
            "[fault.straggler]\nmin_samples = 0\n",
        ] {
            assert!(TrainConfig::from_toml(&Doc::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn inject_keys_parse_a_seeded_fault() {
        let doc = Doc::parse(
            "[fault]\ninject_kind = \"slow\"\ninject_rank = 2\n\
             inject_step = 3\ninject_millis = 80\n",
        )
        .unwrap();
        let inj = TrainConfig::from_toml(&doc).unwrap().fault.inject.unwrap();
        assert_eq!(inj, InjectedFault::slow_at(2, 3, 80));

        let doc = Doc::parse(
            "[fault]\ninject_kind = \"hang\"\ninject_rank = 1\n\
             inject_step = 6\ninject_millis = 500\ninject_attempts = 2\n",
        )
        .unwrap();
        let inj = TrainConfig::from_toml(&doc).unwrap().fault.inject.unwrap();
        assert_eq!(inj.kind, FaultKind::Hang { millis: 500 });
        assert_eq!(inj.attempts, 2);

        let doc = Doc::parse("[fault]\ninject_kind = \"meteor\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn toml_rejects_bad_wire() {
        let doc = Doc::parse("grad_wire = \"fp8\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn transport_config_defaults_and_parses() {
        let c = TrainConfig::quickstart();
        assert_eq!(c.transport.mode, "memory");
        assert_eq!(c.transport.bind, "127.0.0.1:7070");
        assert!(c.transport.http.is_empty());

        let doc = Doc::parse(
            "[transport]\nmode = \"tcp\"\nbind = \"0.0.0.0:9000\"\n\
             http = \"127.0.0.1:9001\"\nmax_frame_bytes = 1048576\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.transport.mode, "tcp");
        assert_eq!(c.transport.bind, "0.0.0.0:9000");
        assert_eq!(c.transport.http, "127.0.0.1:9001");
        assert_eq!(c.transport.max_frame_bytes, 1 << 20);

        // unknown mode and unusably small frame caps are config errors
        let doc = Doc::parse("[transport]\nmode = \"carrier-pigeon\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Doc::parse("[transport]\nmax_frame_bytes = 16\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn transport_backoff_defaults_and_parses() {
        let c = TrainConfig::quickstart();
        assert_eq!(c.transport.backoff, BackoffConfig::default());
        assert_eq!(c.transport.reconnect_attempts, 0, "reconnect is opt-in");
        assert_eq!(c.transport.resync_window, 64);

        let doc = Doc::parse(
            "[transport]\nretry_base_ms = 10\nretry_max_ms = 80\n\
             retry_attempts = 5\nretry_jitter = 0.5\n\
             reconnect_attempts = 3\nresync_window = 16\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.transport.backoff.base, Duration::from_millis(10));
        assert_eq!(c.transport.backoff.max, Duration::from_millis(80));
        assert_eq!(c.transport.backoff.attempts, 5);
        assert_eq!(c.transport.backoff.jitter, 0.5);
        assert_eq!(c.transport.reconnect_attempts, 3);
        assert_eq!(c.transport.resync_window, 16);

        // degenerate backoff shapes are config errors
        for bad in [
            "[transport]\nretry_base_ms = 0\n",
            "[transport]\nretry_base_ms = 100\nretry_max_ms = 50\n",
            "[transport]\nretry_attempts = 0\n",
            "[transport]\nretry_jitter = 1.5\n",
            "[transport]\nreconnect_attempts = 1\nresync_window = 0\n",
        ] {
            assert!(TrainConfig::from_toml(&Doc::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn chaos_and_rejoin_config_defaults_and_parse() {
        let c = TrainConfig::quickstart();
        assert!(!c.fault.chaos.enabled, "chaos must default off");
        assert_eq!(c.fault.rejoin_grace, Duration::ZERO);

        let doc = Doc::parse(
            "[fault]\nrejoin_grace_ms = 4000\n\
             [fault.chaos]\nenabled = true\nseed = 99\ndelay_prob = 0.25\n\
             delay_us_max = 300\ndrop_prob = 0.1\ndrop_delay_us = 700\n\
             dup_prob = 0.05\nreorder_prob = 0.2\nslow_prob = 0.3\n\
             slow_factor = 5.0\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.fault.rejoin_grace, Duration::from_millis(4000));
        let ch = &c.fault.chaos;
        assert!(ch.enabled);
        assert_eq!(ch.seed, 99);
        assert_eq!(ch.delay_prob, 0.25);
        assert_eq!(ch.delay_us_max, 300);
        assert_eq!(ch.drop_prob, 0.1);
        assert_eq!(ch.drop_delay_us, 700);
        assert_eq!(ch.dup_prob, 0.05);
        assert_eq!(ch.reorder_prob, 0.2);
        assert_eq!(ch.slow_prob, 0.3);
        assert_eq!(ch.slow_factor, 5.0);

        // probabilities outside [0,1] are config errors
        let doc = Doc::parse("[fault.chaos]\ndrop_prob = 1.5\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Doc::parse("[fault.chaos]\ndup_prob = -0.1\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Doc::parse("[fault.chaos]\nslow_prob = 2.0\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Doc::parse("[fault.chaos]\nslow_factor = 0.5\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn checkpoint_config_defaults_and_parses() {
        let c = TrainConfig::quickstart();
        assert!(!c.checkpoint.enabled(), "durability must default off");
        assert_eq!(c.checkpoint.every_steps, 0);
        assert_eq!(c.checkpoint.keep_last, 3);

        let doc = Doc::parse(
            "[checkpoint]\nevery_steps = 8\nkeep_last = 2\ndir = \"/tmp/durable\"\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert!(c.checkpoint.enabled());
        assert_eq!(c.checkpoint.every_steps, 8);
        assert_eq!(c.checkpoint.keep_last, 2);
        assert_eq!(c.checkpoint.dir, "/tmp/durable");

        // keep_last = 0 with durability on would GC every snapshot away
        let doc = Doc::parse("[checkpoint]\ndir = \"/tmp/d\"\nkeep_last = 0\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        // ...but is harmless while durability is off
        let doc = Doc::parse("[checkpoint]\nkeep_last = 0\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_ok());
    }

    #[test]
    fn coordinator_grace_defaults_and_parses() {
        let c = TrainConfig::quickstart();
        assert_eq!(c.fault.coordinator_grace, Duration::ZERO, "orphan hold is opt-in");

        let doc = Doc::parse("[fault]\ncoordinator_grace_ms = 15000\n").unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.fault.coordinator_grace, Duration::from_millis(15000));
    }
}
