//! Paper experiment presets: Table 3 (training configurations) and the
//! Table 5 expectations (accuracy/time) they produced.
//!
//! Exact worker counts for the phases are reconstructed from the printed
//! totals ("34K", "68K", …) and per-worker batches; where the paper rounds
//! (e.g. 68K at 16/worker under a 4096-GPU cap) we use the nearest
//! consistent count and note it in EXPERIMENTS.md.

use crate::sched::{BatchSchedule, LrSchedule, Phase};

/// LR configuration selector (paper Table 3 "LR" column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrConfig {
    /// Reference row: settings from [10] (LARS paper).
    Reference,
    /// Config A (TensorFlow-repo recipe).
    A,
    /// Config B (paper's formula block).
    B,
}

impl LrConfig {
    pub fn schedule(self) -> LrSchedule {
        match self {
            // [10] trains 90 epochs with poly decay and 5-epoch warmup —
            // structurally config B's low branch without the 50 switch.
            LrConfig::Reference => LrSchedule::ConfigB {
                warmup_epochs: 5.0,
                warmup_start: 0.2,
                base_low: 29.0,
                base_high: 29.0,
                switch_epoch: 30.0,
                total_epochs: 90.0,
            },
            LrConfig::A => LrSchedule::config_a(),
            LrConfig::B => LrSchedule::config_b(),
        }
    }
}

/// One row of Table 3 + its Table 5 outcome.
#[derive(Debug, Clone)]
pub struct PaperRun {
    pub name: &'static str,
    pub gpus_max: usize,
    pub label_smoothing: f32,
    pub lr: LrConfig,
    pub schedule: BatchSchedule,
    /// Table 5: top-1 validation accuracy (%).
    pub paper_accuracy: f64,
    /// Table 5: wall-clock training time (seconds).
    pub paper_secs: f64,
}

/// All five rows of Tables 3/5: Reference + Exp. 1–4.
pub fn paper_runs() -> Vec<PaperRun> {
    vec![
        PaperRun {
            name: "reference",
            gpus_max: 1024,
            label_smoothing: 0.0,
            lr: LrConfig::Reference,
            schedule: BatchSchedule::constant(32, 1024, 90),
            paper_accuracy: 75.40,
            paper_secs: 505.0,
        },
        PaperRun {
            name: "exp1",
            gpus_max: 2176,
            label_smoothing: 0.0,
            lr: LrConfig::A,
            schedule: BatchSchedule::new(
                vec![
                    Phase { from_epoch: 0, per_worker: 16, workers: 2176 },  // 34K
                    Phase { from_epoch: 30, per_worker: 32, workers: 2176 }, // 68K
                ],
                90,
            ),
            paper_accuracy: 75.03,
            paper_secs: 224.0,
        },
        PaperRun {
            name: "exp2",
            gpus_max: 3456,
            label_smoothing: 0.1,
            lr: LrConfig::B,
            schedule: BatchSchedule::new(
                vec![
                    Phase { from_epoch: 0, per_worker: 16, workers: 3456 },  // 54K
                    Phase { from_epoch: 30, per_worker: 32, workers: 1728 }, // 54K
                ],
                90,
            ),
            paper_accuracy: 75.29,
            paper_secs: 122.0,
        },
        PaperRun {
            name: "exp3",
            gpus_max: 3456,
            label_smoothing: 0.1,
            lr: LrConfig::B,
            schedule: BatchSchedule::new(
                vec![
                    Phase { from_epoch: 0, per_worker: 16, workers: 3456 },  // 54K
                    Phase { from_epoch: 30, per_worker: 32, workers: 2000 }, // 64K
                ],
                90,
            ),
            paper_accuracy: 74.62,
            paper_secs: 115.0,
        },
        PaperRun {
            name: "exp4",
            gpus_max: 4096,
            label_smoothing: 0.1,
            lr: LrConfig::A,
            schedule: BatchSchedule::new(
                vec![
                    Phase { from_epoch: 0, per_worker: 16, workers: 2176 },  // 34K
                    Phase { from_epoch: 30, per_worker: 16, workers: 4096 }, // 68K
                    Phase { from_epoch: 45, per_worker: 32, workers: 2656 }, // 85K
                    Phase { from_epoch: 75, per_worker: 32, workers: 3712 }, // 119K
                ],
                90,
            ),
            paper_accuracy: 75.23,
            paper_secs: 129.0,
        },
    ]
}

/// Look up a paper run by name.
pub fn paper_run(name: &str) -> Option<PaperRun> {
    paper_runs().into_iter().find(|r| r.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_rows_with_table5_bounds() {
        let runs = paper_runs();
        assert_eq!(runs.len(), 5);
        for r in &runs {
            assert!(r.paper_accuracy > 74.0 && r.paper_accuracy < 76.0);
            assert!(r.schedule.max_workers() <= r.gpus_max);
        }
    }

    #[test]
    fn exp2_is_the_headline_122s_run() {
        let r = paper_run("exp2").unwrap();
        assert_eq!(r.paper_secs, 122.0);
        assert_eq!(r.schedule.at(0).total_batch(), 55_296); // "54K"
        assert_eq!(r.schedule.at(30).total_batch(), 55_296); // stays 54K
        assert_eq!(r.label_smoothing, 0.1);
        assert_eq!(r.lr, LrConfig::B);
    }

    #[test]
    fn exp4_batch_range_is_34k_to_119k() {
        let r = paper_run("exp4").unwrap();
        assert_eq!(r.schedule.min_total_batch(), 34_816);
        assert_eq!(r.schedule.max_total_batch(), 118_784); // "119K"
        assert_eq!(r.label_smoothing, 0.1);
    }

    #[test]
    fn reference_has_no_stabilisers() {
        let r = paper_run("reference").unwrap();
        assert_eq!(r.label_smoothing, 0.0);
        assert_eq!(r.schedule.phases().len(), 1);
    }

    #[test]
    fn lr_configs_resolve() {
        assert_eq!(LrConfig::A.schedule(), LrSchedule::config_a());
        assert_eq!(LrConfig::B.schedule(), LrSchedule::config_b());
        // Reference never switches to base 50
        let s = LrConfig::Reference.schedule();
        assert!(s.lr(40.0) < s.lr(29.0));
    }
}
