//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (`artifacts/manifest.json`).
//!
//! The manifest records, per model architecture: the parameter table
//! (names/shapes/sizes in `jax.tree_util` flatten order), the BN-stat layer
//! list, and every lowered executable's input/output tensor specs. The Rust
//! side never guesses shapes — everything flows from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// dtype of a tensor as recorded by the AOT pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?} in manifest"),
        }
    }

    pub fn bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one executable input/output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.get("dtype")?.as_str()?)?;
        Ok(Self { shape, dtype })
    }
}

/// One named parameter tensor (flatten-order position is its index).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
}

/// One BN layer exporting (mean, sqmean) stats of `width` channels.
#[derive(Debug, Clone)]
pub struct BnLayer {
    pub name: String,
    pub width: usize,
}

/// One lowered executable.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Per-worker batch (grad_/eval_ entries).
    pub batch: Option<usize>,
    /// Label smoothing baked into this grad entry.
    pub ls_eps: Option<f64>,
}

/// Everything the runtime knows about one architecture.
#[derive(Debug, Clone)]
pub struct ArchManifest {
    pub name: String,
    pub params: Vec<ParamSpec>,
    pub total_params: usize,
    pub bn_layers: Vec<BnLayer>,
    pub num_classes: usize,
    pub image_size: usize,
    pub image_channels: usize,
    pub executables: BTreeMap<String, ExecSpec>,
}

impl ArchManifest {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn n_bn(&self) -> usize {
        self.bn_layers.len()
    }

    /// Grad executable for `(batch, ls_eps)` — how batch-size control picks
    /// the right artifact (naming scheme from aot.py: `grad_b{B}_ls{E*100}`).
    pub fn grad_exec(&self, batch: usize, ls_eps: f32) -> Result<&ExecSpec> {
        let name = format!("grad_b{batch}_ls{}", (ls_eps * 100.0).round() as i64);
        self.executables.get(&name).ok_or_else(|| {
            anyhow!(
                "{}: no grad executable {name:?}; available: {:?}",
                self.name,
                self.executables.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Grad batch sizes available (ascending) for this LS setting.
    pub fn grad_batches(&self, ls_eps: f32) -> Vec<usize> {
        let suffix = format!("_ls{}", (ls_eps * 100.0).round() as i64);
        let mut v: Vec<usize> = self
            .executables
            .iter()
            .filter(|(k, _)| k.starts_with("grad_b") && k.ends_with(&suffix))
            .filter_map(|(_, e)| e.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// The (single) eval executable.
    pub fn eval_exec(&self) -> Result<&ExecSpec> {
        self.executables
            .values()
            .find(|e| e.name.starts_with("eval_"))
            .ok_or_else(|| anyhow!("{}: no eval executable", self.name))
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("{}: no executable {name:?}", self.name))
    }
}

/// The parsed manifest for all architectures.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub arches: BTreeMap<String, ArchManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let version = j.get("format_version")?.as_usize()?;
        if version != 1 {
            bail!("manifest format_version {version} unsupported (want 1)");
        }
        let mut arches = BTreeMap::new();
        for (name, aj) in j.get("arches")?.as_obj()? {
            arches.insert(name.clone(), Self::parse_arch(name, aj)?);
        }
        Ok(Self { dir, arches })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchManifest> {
        self.arches.get(name).ok_or_else(|| {
            anyhow!(
                "arch {name:?} not in manifest; have {:?}. Re-run `make artifacts`",
                self.arches.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of an executable's HLO text file.
    pub fn hlo_path(&self, exec: &ExecSpec) -> PathBuf {
        self.dir.join(&exec.file)
    }

    fn parse_arch(name: &str, j: &Json) -> Result<ArchManifest> {
        let cfg = j.get("config")?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    size: p.get("size")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let bn_layers = j
            .get("bn_layers")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BnLayer {
                    name: b.get("name")?.as_str()?.to_string(),
                    width: b.get("width")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut executables = BTreeMap::new();
        for (ename, ej) in j.get("executables")?.as_obj()? {
            let inputs = ej
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = ej
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            executables.insert(
                ename.clone(),
                ExecSpec {
                    name: ename.clone(),
                    file: ej.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                    batch: ej.opt("batch").map(|b| b.as_usize()).transpose()?,
                    ls_eps: ej.opt("ls_eps").map(|e| e.as_f64()).transpose()?,
                },
            );
        }
        Ok(ArchManifest {
            name: name.to_string(),
            params,
            total_params: j.get("total_params")?.as_usize()?,
            bn_layers,
            num_classes: cfg.get("num_classes")?.as_usize()?,
            image_size: cfg.get("image_size")?.as_usize()?,
            image_channels: cfg.get("image_channels")?.as_usize()?,
            executables,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    fn have_artifacts() -> bool {
        std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(ARTIFACTS).unwrap();
        let tiny = m.arch("tiny").unwrap();
        assert!(tiny.total_params > 10_000);
        assert_eq!(
            tiny.params.iter().map(|p| p.size).sum::<usize>(),
            tiny.total_params
        );
        // parameter shapes multiply out to sizes
        for p in &tiny.params {
            assert_eq!(p.shape.iter().product::<usize>(), p.size, "{}", p.name);
        }
        assert!(tiny.n_bn() >= 7);
    }

    #[test]
    fn grad_exec_lookup_and_batches() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(ARTIFACTS).unwrap();
        let tiny = m.arch("tiny").unwrap();
        let g = tiny.grad_exec(8, 0.1).unwrap();
        assert_eq!(g.batch, Some(8));
        assert_eq!(g.ls_eps, Some(0.1));
        // io arity contract: params + images + labels in
        assert_eq!(g.inputs.len(), tiny.n_params() + 2);
        assert_eq!(g.outputs.len(), 1 + tiny.n_params() + tiny.n_bn());
        let batches = tiny.grad_batches(0.1);
        assert!(batches.len() >= 2, "{batches:?}");
        assert!(batches.windows(2).all(|w| w[0] < w[1]));
        assert!(tiny.grad_exec(999, 0.1).is_err());
    }

    #[test]
    fn missing_arch_is_helpful_error() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(ARTIFACTS).unwrap();
        let err = m.arch("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}
