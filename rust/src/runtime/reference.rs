//! Pure-Rust reference backend: the default [`super::backend::ComputeBackend`].
//!
//! Serves the same executable contract the AOT/PJRT pipeline serves —
//! `init`, `grad_b{B}_ls{S}`, `apply`, `eval_b{B}` — for a built-in "tiny"
//! architecture, against a synthesized in-memory [`Manifest`]. This is what
//! lets the full training stack (batch-size control, 2D-torus all-reduce,
//! FP16 gradient wire, LARS, checkpoint/resume) run and be tested
//! end-to-end with no Python, no artifact files and no XLA.
//!
//! The model is a dense ResNet-ish network over the 16×16×3 synthetic
//! images: a linear stem, three residual blocks (`linear → BN → ReLU →
//! linear → BN → +skip → ReLU`), and a linear head, trained with
//! label-smoothed softmax cross-entropy. Like the paper's ResNet-50
//! (§3.2), every BN layer exports per-feature `(mean, mean-of-squares)`
//! batch statistics; training normalises with the *current* batch
//! statistics and evaluation uses the synchronized running statistics the
//! coordinator maintains ("BN without moving average"). `apply` is the
//! exact [`crate::optim::lars_step`] update — the same formula the Pallas
//! kernel implements — so reference and PJRT backends are interchangeable
//! from the coordinator's point of view.
//!
//! Forward and backward are hand-derived; `tests::finite_difference_check`
//! verifies the analytic gradients against central differences.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::optim::{lars_step, LarsConfig};
use crate::util::rng::Pcg32;

use super::backend::{
    check_state_tensors, ApplyParams, ComputeBackend, ResidentState, StateId, StateTable,
};
use super::manifest::{ArchManifest, BnLayer, Dtype, ExecSpec, Manifest, ParamSpec, TensorSpec};
use super::tensor::HostTensor;

/// The one architecture the reference backend implements.
pub const TINY_ARCH: &str = "tiny";

const IMG: usize = 16;
const CH: usize = 3;
const IN: usize = IMG * IMG * CH;
const HIDDEN: usize = 32;
const CLASSES: usize = 10;
const N_BLOCKS: usize = 3;
const BN_EPS: f32 = 1e-5;

/// Param-table indices (flatten order; grads come back in the same order).
const P_STEM_W: usize = 0;
const P_STEM_G: usize = 1;
const P_STEM_B: usize = 2;
const P_BLOCK0: usize = 3; // +k*6: w1, bn1/gamma, bn1/beta, w2, bn2/gamma, bn2/beta
const P_HEAD_W: usize = P_BLOCK0 + N_BLOCKS * 6;
const P_HEAD_B: usize = P_HEAD_W + 1;
const N_PARAMS: usize = P_HEAD_B + 1;
const N_BN: usize = 1 + 2 * N_BLOCKS;

/// Grad variants baked into the synthetic manifest (per-worker batches ×
/// label-smoothing settings), mirroring what `aot.py` would lower.
const GRAD_BATCHES: &[usize] = &[1, 2, 4, 8, 16, 32, 64];
const LS_GRID: &[f32] = &[0.0, 0.1];
const EVAL_BATCH: usize = 64;

/// The parameter table of the built-in tiny arch.
fn param_specs() -> Vec<ParamSpec> {
    let h = HIDDEN;
    let spec = |name: String, shape: Vec<usize>| {
        let size = shape.iter().product();
        ParamSpec { name, shape, size }
    };
    let mut v = vec![
        spec("stem/w".into(), vec![IN, h]),
        spec("stem/bn/gamma".into(), vec![h]),
        spec("stem/bn/beta".into(), vec![h]),
    ];
    for k in 1..=N_BLOCKS {
        v.push(spec(format!("block{k}/w1"), vec![h, h]));
        v.push(spec(format!("block{k}/bn1/gamma"), vec![h]));
        v.push(spec(format!("block{k}/bn1/beta"), vec![h]));
        v.push(spec(format!("block{k}/w2"), vec![h, h]));
        v.push(spec(format!("block{k}/bn2/gamma"), vec![h]));
        v.push(spec(format!("block{k}/bn2/beta"), vec![h]));
    }
    v.push(spec("head/w".into(), vec![h, CLASSES]));
    v.push(spec("head/b".into(), vec![CLASSES]));
    debug_assert_eq!(v.len(), N_PARAMS);
    v
}

fn bn_layer_specs() -> Vec<BnLayer> {
    let mut v = vec![BnLayer {
        name: "stem/bn".into(),
        width: HIDDEN,
    }];
    for k in 1..=N_BLOCKS {
        for j in 1..=2 {
            v.push(BnLayer {
                name: format!("block{k}/bn{j}"),
                width: HIDDEN,
            });
        }
    }
    debug_assert_eq!(v.len(), N_BN);
    v
}

/// Synthesize the in-memory manifest the reference backend serves. Shape
/// and naming contracts are identical to `aot.py`'s output, so the
/// coordinator cannot tell the backends apart.
pub fn builtin_manifest() -> Manifest {
    let params = param_specs();
    let total_params = params.iter().map(|p| p.size).sum();
    let param_ts: Vec<TensorSpec> = params
        .iter()
        .map(|p| TensorSpec {
            shape: p.shape.clone(),
            dtype: Dtype::F32,
        })
        .collect();
    let bn_ts: Vec<TensorSpec> = (0..N_BN)
        .map(|_| TensorSpec {
            shape: vec![2, HIDDEN],
            dtype: Dtype::F32,
        })
        .collect();
    let scalar = TensorSpec {
        shape: vec![],
        dtype: Dtype::F32,
    };
    let images = |b: usize| TensorSpec {
        shape: vec![b, IMG, IMG, CH],
        dtype: Dtype::F32,
    };
    let labels = |b: usize| TensorSpec {
        shape: vec![b],
        dtype: Dtype::I32,
    };

    let mut executables = BTreeMap::new();
    executables.insert(
        "init".to_string(),
        ExecSpec {
            name: "init".into(),
            file: "<builtin>".into(),
            inputs: vec![TensorSpec {
                shape: vec![1],
                dtype: Dtype::I32,
            }],
            outputs: param_ts.clone(),
            batch: None,
            ls_eps: None,
        },
    );
    let mut apply_in = param_ts.clone();
    apply_in.extend(param_ts.iter().cloned()); // momenta
    apply_in.extend(param_ts.iter().cloned()); // grads
    apply_in.extend([scalar.clone(), scalar.clone(), scalar.clone()]); // lr, momentum, wd
    let mut apply_out = param_ts.clone();
    apply_out.extend(param_ts.iter().cloned());
    executables.insert(
        "apply".to_string(),
        ExecSpec {
            name: "apply".into(),
            file: "<builtin>".into(),
            inputs: apply_in,
            outputs: apply_out,
            batch: None,
            ls_eps: None,
        },
    );
    for &b in GRAD_BATCHES {
        for &ls in LS_GRID {
            let name = format!("grad_b{b}_ls{}", (ls * 100.0).round() as i64);
            let mut inputs = param_ts.clone();
            inputs.push(images(b));
            inputs.push(labels(b));
            let mut outputs = vec![scalar.clone()];
            outputs.extend(param_ts.iter().cloned());
            outputs.extend(bn_ts.iter().cloned());
            executables.insert(
                name.clone(),
                ExecSpec {
                    name,
                    file: "<builtin>".into(),
                    inputs,
                    outputs,
                    batch: Some(b),
                    ls_eps: Some(f64::from(ls)),
                },
            );
        }
    }
    let mut eval_in = param_ts.clone();
    eval_in.extend(bn_ts.iter().cloned());
    eval_in.push(images(EVAL_BATCH));
    eval_in.push(labels(EVAL_BATCH));
    executables.insert(
        format!("eval_b{EVAL_BATCH}"),
        ExecSpec {
            name: format!("eval_b{EVAL_BATCH}"),
            file: "<builtin>".into(),
            inputs: eval_in,
            outputs: vec![scalar.clone(), scalar],
            batch: Some(EVAL_BATCH),
            ls_eps: None,
        },
    );

    let arch = ArchManifest {
        name: TINY_ARCH.to_string(),
        params,
        total_params,
        bn_layers: bn_layer_specs(),
        num_classes: CLASSES,
        image_size: IMG,
        image_channels: CH,
        executables,
    };
    let mut arches = BTreeMap::new();
    arches.insert(TINY_ARCH.to_string(), arch);
    Manifest {
        dir: "<builtin>".into(),
        arches,
    }
}

/// The pure-Rust compute backend.
pub struct ReferenceBackend {
    manifest: Manifest,
    /// Resident per-rank `(params, momenta)` states (session API).
    states: StateTable,
}

impl ReferenceBackend {
    /// Wrap `manifest`; it must describe the built-in tiny architecture
    /// (use [`builtin_manifest`]).
    pub fn new(manifest: Manifest) -> Result<Self> {
        let arch = manifest.arch(TINY_ARCH)?;
        if arch.n_params() != N_PARAMS
            || arch.n_bn() != N_BN
            || arch.image_size != IMG
            || arch.image_channels != CH
            || arch.num_classes != CLASSES
        {
            bail!(
                "reference backend serves only the built-in {TINY_ARCH:?} architecture \
                 ({N_PARAMS} params, {N_BN} bn layers); this manifest does not match"
            );
        }
        Ok(Self {
            manifest,
            states: StateTable::default(),
        })
    }

    /// Look up `exec` of the resident state's arch; returns `(batch,
    /// ls_eps)` copied out of the spec so the manifest borrow ends before
    /// the state is touched mutably.
    fn exec_meta(&self, state: StateId, exec: &str) -> Result<(usize, f32)> {
        let st = self.states.get(state)?;
        let arch = self.manifest.arch(&st.arch)?;
        let spec = arch.exec(exec)?;
        let batch = spec
            .batch
            .with_context(|| format!("{}/{exec}: missing batch", st.arch))?;
        Ok((batch, spec.ls_eps.unwrap_or(0.0) as f32))
    }

    /// Shared input validation of the monolithic and streaming grad entry
    /// points (`what` only flavours the error messages): checks the exec
    /// family and the batch tensors' shapes, returns `(batch, ls_eps)`.
    fn check_grad_inputs(
        &self,
        what: &str,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<(usize, f32)> {
        if !exec.starts_with("grad_") {
            bail!("{what}: {exec:?} is not a grad executable");
        }
        let (batch, ls) = self.exec_meta(state, exec)?;
        let want_img = vec![batch, IMG, IMG, CH];
        if images.shape() != want_img.as_slice() {
            bail!(
                "{what}({exec}): images shape {:?}, want {want_img:?}",
                images.shape()
            );
        }
        if labels.shape() != [batch] {
            bail!(
                "{what}({exec}): labels shape {:?}, want [{batch}]",
                labels.shape()
            );
        }
        Ok((batch, ls))
    }
}

impl ComputeBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load(&mut self, arch: &str, names: &[&str]) -> Result<()> {
        let am = self.manifest.arch(arch)?;
        for name in names {
            am.exec(name)?;
        }
        Ok(())
    }

    fn run(&mut self, key: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (arch_name, exec_name) = key
            .split_once('/')
            .with_context(|| format!("reference backend: key {key:?} is not \"arch/exec\""))?;
        let arch = self.manifest.arch(arch_name)?;
        let spec = arch.exec(exec_name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{key}: wrong input arity {} (want {})",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            t.check(s).with_context(|| format!("{key}: input #{i}"))?;
        }
        if exec_name == "init" {
            return Ok(run_init(inputs[0].as_i32()?[0]));
        }
        if exec_name == "apply" {
            return run_apply(inputs);
        }
        if exec_name.starts_with("grad_") {
            let batch = spec.batch.with_context(|| format!("{key}: missing batch"))?;
            let ls = spec.ls_eps.unwrap_or(0.0) as f32;
            let params = &inputs[..N_PARAMS];
            let images = inputs[N_PARAMS].as_f32()?;
            let labels = inputs[N_PARAMS + 1].as_i32()?;
            return run_grad(params, images, labels, batch, ls);
        }
        if exec_name.starts_with("eval_") {
            let batch = spec.batch.with_context(|| format!("{key}: missing batch"))?;
            let params = &inputs[..N_PARAMS];
            let bn_running = &inputs[N_PARAMS..N_PARAMS + N_BN];
            let images = inputs[N_PARAMS + N_BN].as_f32()?;
            let labels = inputs[N_PARAMS + N_BN + 1].as_i32()?;
            return run_eval(params, bn_running, images, labels, batch);
        }
        bail!("{key}: reference backend has no such entry point")
    }

    // --- session/state API -------------------------------------------------

    fn create_state(&mut self, arch: &str, seed: i32) -> Result<StateId> {
        self.manifest.arch(arch)?; // only "tiny" exists; fail fast otherwise
        let params = run_init(seed);
        let momenta: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
            .collect();
        Ok(self.states.insert(ResidentState {
            arch: arch.to_string(),
            params,
            momenta,
        }))
    }

    fn import_state(
        &mut self,
        arch: &str,
        params: Vec<HostTensor>,
        momenta: Vec<HostTensor>,
    ) -> Result<StateId> {
        check_state_tensors(&self.manifest, arch, &params, &momenta)?;
        Ok(self.states.insert(ResidentState {
            arch: arch.to_string(),
            params,
            momenta,
        }))
    }

    fn export_state(&mut self, state: StateId) -> Result<(Vec<HostTensor>, Vec<HostTensor>)> {
        let st = self.states.remove(state)?;
        Ok((st.params, st.momenta))
    }

    fn drop_state(&mut self, state: StateId) -> Result<()> {
        self.states.remove(state).map(|_| ())
    }

    fn grad_step(
        &mut self,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let (batch, ls) = self.check_grad_inputs("grad_step", state, exec, images, labels)?;
        let st = self.states.get(state)?;
        run_grad(&st.params, images.as_f32()?, labels.as_i32()?, batch, ls)
    }

    fn grad_step_streaming(
        &mut self,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
        emit: &mut dyn FnMut(usize, HostTensor),
    ) -> Result<Vec<HostTensor>> {
        let (batch, ls) =
            self.check_grad_inputs("grad_step_streaming", state, exec, images, labels)?;
        let st = self.states.get(state)?;
        // Genuinely interleaved: `emit` fires from inside the backward
        // pass, layer by layer, so a caller on another thread reduces
        // bucket k while this thread is still producing bucket k+1.
        let (loss, bn) = run_grad_core(
            &st.params,
            images.as_f32()?,
            labels.as_i32()?,
            batch,
            ls,
            emit,
        )?;
        let mut out = Vec::with_capacity(1 + N_BN);
        out.push(HostTensor::scalar_f32(loss));
        out.extend(bn);
        Ok(out)
    }

    fn apply_partial(
        &mut self,
        state: StateId,
        first_param: usize,
        grads: Vec<HostTensor>,
        hp: ApplyParams,
    ) -> Result<()> {
        let st = self.states.get_mut(state)?;
        let n = st.params.len();
        if first_param + grads.len() > n {
            bail!(
                "apply_partial: params [{first_param}, {}) out of range (model has {n})",
                first_param + grads.len()
            );
        }
        let cfg = LarsConfig {
            coeff: 0.01,
            eps: 1e-6,
            weight_decay: hp.weight_decay,
        };
        let params = &mut st.params[first_param..first_param + grads.len()];
        let momenta = &mut st.momenta[first_param..first_param + grads.len()];
        for (i, ((p, m), g)) in params.iter_mut().zip(momenta.iter_mut()).zip(&grads).enumerate() {
            if p.shape() != g.shape() {
                bail!(
                    "apply_partial: grad #{} shape {:?} vs param {:?}",
                    first_param + i,
                    g.shape(),
                    p.shape()
                );
            }
            // Per-tensor LARS: identical arithmetic to `apply`, so a
            // bucket-partitioned update is bit-identical to the whole-model
            // one.
            lars_step(
                p.as_f32_mut()?,
                g.as_f32()?,
                m.as_f32_mut()?,
                hp.lr,
                hp.momentum,
                &cfg,
            );
        }
        Ok(())
    }

    fn apply(&mut self, state: StateId, grads: &[HostTensor], hp: ApplyParams) -> Result<()> {
        let st = self.states.get_mut(state)?;
        if grads.len() != st.params.len() {
            bail!(
                "apply: {} grads for {} resident params",
                grads.len(),
                st.params.len()
            );
        }
        let cfg = LarsConfig {
            coeff: 0.01,
            eps: 1e-6,
            weight_decay: hp.weight_decay,
        };
        for (i, ((p, m), g)) in st
            .params
            .iter_mut()
            .zip(st.momenta.iter_mut())
            .zip(grads)
            .enumerate()
        {
            if p.shape() != g.shape() {
                bail!(
                    "apply: grad #{i} shape {:?} vs param {:?}",
                    g.shape(),
                    p.shape()
                );
            }
            lars_step(
                p.as_f32_mut()?,
                g.as_f32()?,
                m.as_f32_mut()?,
                hp.lr,
                hp.momentum,
                &cfg,
            );
        }
        Ok(())
    }

    fn eval_step(
        &mut self,
        state: StateId,
        exec: &str,
        bn_running: &[HostTensor],
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<Vec<HostTensor>> {
        if !exec.starts_with("eval_") {
            bail!("eval_step: {exec:?} is not an eval executable");
        }
        let (batch, _) = self.exec_meta(state, exec)?;
        if bn_running.len() != N_BN {
            bail!(
                "eval_step({exec}): {} bn tensors, want {N_BN}",
                bn_running.len()
            );
        }
        for (i, t) in bn_running.iter().enumerate() {
            if t.elems() != 2 * HIDDEN {
                bail!(
                    "eval_step({exec}): bn tensor #{i} has {} elems, want {}",
                    t.elems(),
                    2 * HIDDEN
                );
            }
        }
        let want_img = vec![batch, IMG, IMG, CH];
        if images.shape() != want_img.as_slice() {
            bail!(
                "eval_step({exec}): images shape {:?}, want {want_img:?}",
                images.shape()
            );
        }
        if labels.shape() != [batch] {
            bail!(
                "eval_step({exec}): labels shape {:?}, want [{batch}]",
                labels.shape()
            );
        }
        let st = self.states.get(state)?;
        run_eval(&st.params, bn_running, images.as_f32()?, labels.as_i32()?, batch)
    }
}

// ---------------------------------------------------------------------------
// dense-math helpers

/// `out[m,n] += a[m,k] @ b[k,n]` (row-major; `out` pre-sized by the caller).
fn matmul_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for (arow, orow) in a.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (&av, brow) in arow.iter().zip(b.chunks_exact(n)) {
            if av != 0.0 {
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// `out[k,n] += a[m,k]ᵀ @ d[m,n]` — weight gradients.
fn matmul_tn_acc(a: &[f32], d: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for (arow, drow) in a.chunks_exact(k).zip(d.chunks_exact(n)) {
        for (&av, orow) in arow.iter().zip(out.chunks_exact_mut(n)) {
            if av != 0.0 {
                for (o, &dv) in orow.iter_mut().zip(drow) {
                    *o += av * dv;
                }
            }
        }
    }
}

/// `out[m,k] += d[m,n] @ w[k,n]ᵀ` — input gradients.
fn matmul_nt_acc(d: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(d.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for (drow, orow) in d.chunks_exact(n).zip(out.chunks_exact_mut(k)) {
        for (o, wrow) in orow.iter_mut().zip(w.chunks_exact(n)) {
            let mut s = 0.0f32;
            for (&dv, &wv) in drow.iter().zip(wrow) {
                s += dv * wv;
            }
            *o += s;
        }
    }
}

fn relu(mut v: Vec<f32>) -> Vec<f32> {
    for x in v.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
    v
}

/// Zero `d` wherever the forward ReLU output was zero.
fn relu_backward(d: &mut [f32], fwd_out: &[f32]) {
    for (dv, &o) in d.iter_mut().zip(fwd_out) {
        if o <= 0.0 {
            *dv = 0.0;
        }
    }
}

/// Saved forward state of one BN layer (training mode).
struct BnCache {
    /// Normalised input `(z - mean)/std`, `[B*H]`.
    xh: Vec<f32>,
    /// `1/sqrt(var + eps)` per feature.
    inv_std: Vec<f32>,
    /// Batch mean per feature (exported statistic).
    mean: Vec<f32>,
    /// Batch mean of squares per feature (exported statistic).
    sq: Vec<f32>,
}

/// Training-mode BN: normalise with the current batch statistics
/// (paper §3.2, "Batch Normalization without Moving Average").
fn bn_forward_train(
    z: &[f32],
    gamma: &[f32],
    beta: &[f32],
    b: usize,
    h: usize,
) -> (Vec<f32>, BnCache) {
    debug_assert_eq!(z.len(), b * h);
    let mut mean = vec![0.0f32; h];
    let mut sq = vec![0.0f32; h];
    for row in z.chunks_exact(h) {
        for ((m, s), &v) in mean.iter_mut().zip(sq.iter_mut()).zip(row) {
            *m += v;
            *s += v * v;
        }
    }
    let inv_b = 1.0 / b as f32;
    for (m, s) in mean.iter_mut().zip(sq.iter_mut()) {
        *m *= inv_b;
        *s *= inv_b;
    }
    let inv_std: Vec<f32> = mean
        .iter()
        .zip(&sq)
        .map(|(&m, &s)| 1.0 / ((s - m * m).max(0.0) + BN_EPS).sqrt())
        .collect();
    let mut xh = vec![0.0f32; b * h];
    let mut y = vec![0.0f32; b * h];
    for (zrow, (xrow, yrow)) in z
        .chunks_exact(h)
        .zip(xh.chunks_exact_mut(h).zip(y.chunks_exact_mut(h)))
    {
        for j in 0..h {
            let xn = (zrow[j] - mean[j]) * inv_std[j];
            xrow[j] = xn;
            yrow[j] = gamma[j] * xn + beta[j];
        }
    }
    (
        y,
        BnCache {
            xh,
            inv_std,
            mean,
            sq,
        },
    )
}

/// Exact BN backward: `(dz, dgamma, dbeta)` from `dy`.
fn bn_backward(
    dy: &[f32],
    cache: &BnCache,
    gamma: &[f32],
    b: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dgamma = vec![0.0f32; h];
    let mut dbeta = vec![0.0f32; h];
    for (dyrow, xrow) in dy.chunks_exact(h).zip(cache.xh.chunks_exact(h)) {
        for j in 0..h {
            dgamma[j] += dyrow[j] * xrow[j];
            dbeta[j] += dyrow[j];
        }
    }
    let bf = b as f32;
    let mut dz = vec![0.0f32; b * h];
    for ((dyrow, xrow), dzrow) in dy
        .chunks_exact(h)
        .zip(cache.xh.chunks_exact(h))
        .zip(dz.chunks_exact_mut(h))
    {
        for j in 0..h {
            dzrow[j] = gamma[j] * cache.inv_std[j] / bf
                * (bf * dyrow[j] - dbeta[j] - xrow[j] * dgamma[j]);
        }
    }
    (dz, dgamma, dbeta)
}

/// Eval-mode BN: normalise with synchronized running statistics
/// `running = [mean.., mean-of-squares..]`.
fn bn_forward_eval(z: &[f32], gamma: &[f32], beta: &[f32], running: &[f32], h: usize) -> Vec<f32> {
    debug_assert_eq!(running.len(), 2 * h);
    let (mean, sq) = running.split_at(h);
    let scale: Vec<f32> = (0..h)
        .map(|j| gamma[j] / ((sq[j] - mean[j] * mean[j]).max(0.0) + BN_EPS).sqrt())
        .collect();
    let mut y = vec![0.0f32; z.len()];
    for (zrow, yrow) in z.chunks_exact(h).zip(y.chunks_exact_mut(h)) {
        for j in 0..h {
            yrow[j] = scale[j] * (zrow[j] - mean[j]) + beta[j];
        }
    }
    y
}

/// Label-smoothed softmax cross-entropy: `(mean loss, dlogits/B)`.
fn ls_softmax_grad(
    logits: &[f32],
    labels: &[i32],
    b: usize,
    c: usize,
    ls: f32,
) -> (f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; b * c];
    let mut loss_sum = 0.0f64;
    let uniform = ls / c as f32;
    let inv_b = 1.0 / b as f32;
    for ((row, drow), &label) in logits
        .chunks_exact(c)
        .zip(dlogits.chunks_exact_mut(c))
        .zip(labels)
    {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &z in row {
            sum += (z - max).exp();
        }
        let logsum = max + sum.ln();
        let y = label as usize;
        for (j, (&z, d)) in row.iter().zip(drow.iter_mut()).enumerate() {
            let logp = z - logsum;
            let q = uniform + if j == y { 1.0 - ls } else { 0.0 };
            loss_sum -= f64::from(q * logp);
            *d = (logp.exp() - q) * inv_b;
        }
    }
    ((loss_sum / b as f64) as f32, dlogits)
}

fn bn_stats_tensor(cache: &BnCache) -> HostTensor {
    let mut data = cache.mean.clone();
    data.extend_from_slice(&cache.sq);
    HostTensor::f32(vec![2, HIDDEN], data)
}

// ---------------------------------------------------------------------------
// entry points

/// Deterministic He init: weights ~ N(0, 2/fan_in), gamma = 1, beta/bias = 0.
fn run_init(seed: i32) -> Vec<HostTensor> {
    let seed64 = seed as i64 as u64 ^ 0x714_1A2C_11E5_EED5;
    param_specs()
        .iter()
        .enumerate()
        .map(|(idx, p)| {
            let data = if p.shape.len() == 2 {
                let std = (2.0 / p.shape[0] as f32).sqrt();
                let mut rng = Pcg32::with_stream(seed64, idx as u64);
                (0..p.size).map(|_| rng.next_normal() * std).collect()
            } else if p.name.ends_with("gamma") {
                vec![1.0f32; p.size]
            } else {
                vec![0.0f32; p.size]
            };
            HostTensor::f32(p.shape.clone(), data)
        })
        .collect()
}

/// Saved activations of one residual block.
struct BlockFwd {
    input: Vec<f32>,
    r1: Vec<f32>,
    bn1: BnCache,
    bn2: BnCache,
    out: Vec<f32>,
}

/// Forward + backward of the tiny net: `[loss, grads.., bn stats..]`.
/// Thin wrapper over [`run_grad_core`] that collects the streamed
/// gradients back into parameter order — the monolithic and streaming
/// entry points share every arithmetic operation, so they are bit-identical
/// by construction.
fn run_grad(
    params: &[HostTensor],
    images: &[f32],
    labels: &[i32],
    b: usize,
    ls: f32,
) -> Result<Vec<HostTensor>> {
    let mut slots: Vec<Option<HostTensor>> = (0..N_PARAMS).map(|_| None).collect();
    let (loss, bn) = run_grad_core(params, images, labels, b, ls, &mut |idx, t| {
        slots[idx] = Some(t);
    })?;
    let mut out = Vec::with_capacity(1 + N_PARAMS + N_BN);
    out.push(HostTensor::scalar_f32(loss));
    for s in slots {
        out.push(s.expect("run_grad_core emits every parameter gradient"));
    }
    out.extend(bn);
    Ok(out)
}

/// The shared forward + backward. Each parameter gradient is passed to
/// `emit(param_index, grad)` as soon as the backward pass finalises it —
/// in **strictly decreasing parameter index** (reverse layer order:
/// head, block N..1, stem), exactly once each. Returns
/// `(loss, bn_stats)`.
fn run_grad_core(
    params: &[HostTensor],
    images: &[f32],
    labels: &[i32],
    b: usize,
    ls: f32,
    emit: &mut dyn FnMut(usize, HostTensor),
) -> Result<(f32, Vec<HostTensor>)> {
    let h = HIDDEN;

    // --- forward ---
    let w0 = params[P_STEM_W].as_f32()?;
    let g0 = params[P_STEM_G].as_f32()?;
    let be0 = params[P_STEM_B].as_f32()?;
    let mut z0 = vec![0.0f32; b * h];
    matmul_acc(images, w0, b, IN, h, &mut z0);
    let (y0, bn0) = bn_forward_train(&z0, g0, be0, b, h);
    let mut act = relu(y0);

    let mut blocks: Vec<BlockFwd> = Vec::with_capacity(N_BLOCKS);
    for k in 0..N_BLOCKS {
        let base = P_BLOCK0 + k * 6;
        let w1 = params[base].as_f32()?;
        let g1 = params[base + 1].as_f32()?;
        let be1 = params[base + 2].as_f32()?;
        let w2 = params[base + 3].as_f32()?;
        let g2 = params[base + 4].as_f32()?;
        let be2 = params[base + 5].as_f32()?;
        let mut z1 = vec![0.0f32; b * h];
        matmul_acc(&act, w1, b, h, h, &mut z1);
        let (y1, bn1) = bn_forward_train(&z1, g1, be1, b, h);
        let r1 = relu(y1);
        let mut z2 = vec![0.0f32; b * h];
        matmul_acc(&r1, w2, b, h, h, &mut z2);
        let (mut s, bn2) = bn_forward_train(&z2, g2, be2, b, h);
        for (sv, &av) in s.iter_mut().zip(&act) {
            *sv += av; // residual add
        }
        let out = relu(s);
        let input = act;
        act = out.clone();
        blocks.push(BlockFwd {
            input,
            r1,
            bn1,
            bn2,
            out,
        });
    }

    let wh = params[P_HEAD_W].as_f32()?;
    let bh = params[P_HEAD_B].as_f32()?;
    let mut logits = vec![0.0f32; b * CLASSES];
    matmul_acc(&act, wh, b, h, CLASSES, &mut logits);
    for row in logits.chunks_exact_mut(CLASSES) {
        for (l, &bias) in row.iter_mut().zip(bh) {
            *l += bias;
        }
    }
    let (loss, dlogits) = ls_softmax_grad(&logits, labels, b, CLASSES, ls);

    // --- backward (each layer's gradients emitted as soon as they are
    // final; nothing downstream ever touches an emitted gradient again,
    // which is what makes the streaming overlap sound) ---
    let shape = |idx: usize| params[idx].shape().to_vec();

    let mut g_head_w = vec![0.0f32; h * CLASSES];
    matmul_tn_acc(&act, &dlogits, b, h, CLASSES, &mut g_head_w);
    let mut g_head_b = vec![0.0f32; CLASSES];
    for drow in dlogits.chunks_exact(CLASSES) {
        for (gb, &d) in g_head_b.iter_mut().zip(drow) {
            *gb += d;
        }
    }
    emit(P_HEAD_B, HostTensor::f32(shape(P_HEAD_B), g_head_b));
    emit(P_HEAD_W, HostTensor::f32(shape(P_HEAD_W), g_head_w));
    let mut dact = vec![0.0f32; b * h];
    matmul_nt_acc(&dlogits, wh, b, h, CLASSES, &mut dact);

    for k in (0..N_BLOCKS).rev() {
        let base = P_BLOCK0 + k * 6;
        let w1 = params[base].as_f32()?;
        let g1 = params[base + 1].as_f32()?;
        let w2 = params[base + 3].as_f32()?;
        let g2 = params[base + 4].as_f32()?;
        let blk = &blocks[k];

        let mut ds = dact; // gradient at the post-residual ReLU output
        relu_backward(&mut ds, &blk.out);

        let (dz2, dg2, db2) = bn_backward(&ds, &blk.bn2, g2, b, h);
        let mut gw2 = vec![0.0f32; h * h];
        matmul_tn_acc(&blk.r1, &dz2, b, h, h, &mut gw2);
        let mut dr1 = vec![0.0f32; b * h];
        matmul_nt_acc(&dz2, w2, b, h, h, &mut dr1);
        relu_backward(&mut dr1, &blk.r1);

        let (dz1, dg1, db1) = bn_backward(&dr1, &blk.bn1, g1, b, h);
        let mut gw1 = vec![0.0f32; h * h];
        matmul_tn_acc(&blk.input, &dz1, b, h, h, &mut gw1);

        emit(base + 5, HostTensor::f32(shape(base + 5), db2));
        emit(base + 4, HostTensor::f32(shape(base + 4), dg2));
        emit(base + 3, HostTensor::f32(shape(base + 3), gw2));
        emit(base + 2, HostTensor::f32(shape(base + 2), db1));
        emit(base + 1, HostTensor::f32(shape(base + 1), dg1));
        emit(base, HostTensor::f32(shape(base), gw1));

        // block-input grad: main path + the residual skip (ds).
        let mut dinput = ds;
        matmul_nt_acc(&dz1, w1, b, h, h, &mut dinput);
        dact = dinput;
    }

    let g0 = params[P_STEM_G].as_f32()?;
    let mut dy0 = dact;
    relu_backward(&mut dy0, &blocks[0].input);
    let (dz0, dg0, db0) = bn_backward(&dy0, &bn0, g0, b, h);
    let mut g_stem_w = vec![0.0f32; IN * h];
    matmul_tn_acc(images, &dz0, b, IN, h, &mut g_stem_w);
    emit(P_STEM_B, HostTensor::f32(shape(P_STEM_B), db0));
    emit(P_STEM_G, HostTensor::f32(shape(P_STEM_G), dg0));
    emit(P_STEM_W, HostTensor::f32(shape(P_STEM_W), g_stem_w));

    // --- bn stats (layer order) ---
    let mut bn = Vec::with_capacity(N_BN);
    bn.push(bn_stats_tensor(&bn0));
    for blk in &blocks {
        bn.push(bn_stats_tensor(&blk.bn1));
        bn.push(bn_stats_tensor(&blk.bn2));
    }
    Ok((loss, bn))
}

/// Eval with synchronized running BN statistics: `[loss sum, #correct]`.
fn run_eval(
    params: &[HostTensor],
    bn_running: &[HostTensor],
    images: &[f32],
    labels: &[i32],
    b: usize,
) -> Result<Vec<HostTensor>> {
    let h = HIDDEN;
    let mut bn_idx = 0usize;
    let mut next_bn = |gamma: &[f32], beta: &[f32], z: &[f32]| -> Result<Vec<f32>> {
        let running = bn_running[bn_idx].as_f32()?;
        bn_idx += 1;
        Ok(bn_forward_eval(z, gamma, beta, running, h))
    };

    let w0 = params[P_STEM_W].as_f32()?;
    let mut z0 = vec![0.0f32; b * h];
    matmul_acc(images, w0, b, IN, h, &mut z0);
    let y0 = next_bn(params[P_STEM_G].as_f32()?, params[P_STEM_B].as_f32()?, &z0)?;
    let mut act = relu(y0);

    for k in 0..N_BLOCKS {
        let base = P_BLOCK0 + k * 6;
        let mut z1 = vec![0.0f32; b * h];
        matmul_acc(&act, params[base].as_f32()?, b, h, h, &mut z1);
        let y1 = next_bn(
            params[base + 1].as_f32()?,
            params[base + 2].as_f32()?,
            &z1,
        )?;
        let r1 = relu(y1);
        let mut z2 = vec![0.0f32; b * h];
        matmul_acc(&r1, params[base + 3].as_f32()?, b, h, h, &mut z2);
        let mut s = next_bn(
            params[base + 4].as_f32()?,
            params[base + 5].as_f32()?,
            &z2,
        )?;
        for (sv, &av) in s.iter_mut().zip(&act) {
            *sv += av;
        }
        act = relu(s);
    }

    let wh = params[P_HEAD_W].as_f32()?;
    let bh = params[P_HEAD_B].as_f32()?;
    let mut logits = vec![0.0f32; b * CLASSES];
    matmul_acc(&act, wh, b, h, CLASSES, &mut logits);
    for row in logits.chunks_exact_mut(CLASSES) {
        for (l, &bias) in row.iter_mut().zip(bh) {
            *l += bias;
        }
    }

    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f32;
    for (row, &label) in logits.chunks_exact(CLASSES).zip(labels) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &z in row {
            sum += (z - max).exp();
        }
        let logsum = max + sum.ln();
        let y = label as usize;
        loss_sum -= f64::from(row[y] - logsum);
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0);
        if argmax == y {
            correct += 1.0;
        }
    }
    Ok(vec![
        HostTensor::scalar_f32(loss_sum as f32),
        HostTensor::scalar_f32(correct),
    ])
}

/// LARS update, per tensor — the exact formula of the Pallas `apply`
/// artifact: `[params'.., momenta'..]`.
fn run_apply(inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let (params, rest) = inputs.split_at(N_PARAMS);
    let (momenta, rest) = rest.split_at(N_PARAMS);
    let (grads, scalars) = rest.split_at(N_PARAMS);
    let lr = scalars[0].scalar()?;
    let momentum = scalars[1].scalar()?;
    let weight_decay = scalars[2].scalar()?;
    let cfg = LarsConfig {
        coeff: 0.01,
        eps: 1e-6,
        weight_decay,
    };
    let mut new_params = Vec::with_capacity(N_PARAMS);
    let mut new_momenta = Vec::with_capacity(N_PARAMS);
    for ((p, m), g) in params.iter().zip(momenta).zip(grads) {
        let mut w = p.as_f32()?.to_vec();
        let mut v = m.as_f32()?.to_vec();
        lars_step(&mut w, g.as_f32()?, &mut v, lr, momentum, &cfg);
        new_params.push(HostTensor::f32(p.shape().to_vec(), w));
        new_momenta.push(HostTensor::f32(m.shape().to_vec(), v));
    }
    let mut out = new_params;
    out.extend(new_momenta);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDataset;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(builtin_manifest()).unwrap()
    }

    fn init_params(seed: i32) -> Vec<HostTensor> {
        backend()
            .run("tiny/init", &[HostTensor::i32(vec![1], vec![seed])])
            .unwrap()
    }

    fn sample_batch(b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let ds = SynthDataset::tiny(seed);
        let mut images = vec![0.0f32; b * ds.pixels()];
        let mut labels = vec![0i32; b];
        for i in 0..b {
            ds.train_image(i, &mut images[i * ds.pixels()..(i + 1) * ds.pixels()]);
            labels[i] = ds.train_label(i);
        }
        (images, labels)
    }

    fn grad_inputs(params: &[HostTensor], b: usize) -> Vec<HostTensor> {
        let (images, labels) = sample_batch(b, 3);
        let mut inputs = params.to_vec();
        inputs.push(HostTensor::f32(vec![b, IMG, IMG, CH], images));
        inputs.push(HostTensor::i32(vec![b], labels));
        inputs
    }

    #[test]
    fn builtin_manifest_satisfies_the_artifact_contract() {
        let m = builtin_manifest();
        let tiny = m.arch(TINY_ARCH).unwrap();
        assert!(tiny.total_params > 10_000);
        assert_eq!(
            tiny.params.iter().map(|p| p.size).sum::<usize>(),
            tiny.total_params
        );
        for p in &tiny.params {
            assert_eq!(p.shape.iter().product::<usize>(), p.size, "{}", p.name);
        }
        assert!(tiny.n_bn() >= 7);
        let g = tiny.grad_exec(8, 0.1).unwrap();
        assert_eq!(g.batch, Some(8));
        assert_eq!(g.inputs.len(), tiny.n_params() + 2);
        assert_eq!(g.outputs.len(), 1 + tiny.n_params() + tiny.n_bn());
        let batches = tiny.grad_batches(0.1);
        assert!(batches.len() >= 2, "{batches:?}");
        assert!(batches.windows(2).all(|w| w[0] < w[1]));
        assert!(tiny.grad_exec(999, 0.1).is_err());
        assert!(tiny.eval_exec().is_ok());
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let a = init_params(7);
        let b = init_params(7);
        let c = init_params(8);
        assert_eq!(a.len(), N_PARAMS);
        assert_eq!(a, b);
        assert_ne!(a[P_STEM_W], c[P_STEM_W]);
        // gamma ones, beta zeros
        assert!(a[P_STEM_G].as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(a[P_STEM_B].as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn initial_loss_is_near_ln_classes() {
        let params = init_params(7);
        let mut be = backend();
        let out = be.run("tiny/grad_b8_ls10", &grad_inputs(&params, 8)).unwrap();
        assert_eq!(out.len(), 1 + N_PARAMS + N_BN);
        let loss = out[0].scalar().unwrap();
        // 10 classes: ln(10) ≈ 2.303; BN keeps logits tame at init.
        assert!(loss.is_finite() && loss > 1.5 && loss < 4.0, "loss {loss}");
        // every grad is finite and at least one is non-zero
        let mut norm = 0.0f64;
        for g in &out[1..1 + N_PARAMS] {
            for &x in g.as_f32().unwrap() {
                assert!(x.is_finite());
                norm += f64::from(x) * f64::from(x);
            }
        }
        assert!(norm > 0.0);
    }

    #[test]
    fn bn_stats_are_the_batch_moments() {
        // stats exported by grad must be the actual per-feature moments:
        // check the normalisation identity E[x²] ≥ E[x]² and shape.
        let params = init_params(1);
        let mut be = backend();
        let out = be.run("tiny/grad_b8_ls10", &grad_inputs(&params, 8)).unwrap();
        for stats in &out[1 + N_PARAMS..] {
            assert_eq!(stats.shape(), &[2, HIDDEN]);
            let d = stats.as_f32().unwrap();
            let (mean, sq) = d.split_at(HIDDEN);
            for (m, s) in mean.iter().zip(sq) {
                assert!(s + 1e-5 >= m * m, "E[x²]={s} < E[x]²={}", m * m);
            }
        }
    }

    #[test]
    fn finite_difference_check() {
        // Central differences against the analytic gradients, at the
        // largest-|grad| coordinate of a representative tensor per layer
        // type (weights, gamma, beta, head).
        let b = 4usize;
        let params = init_params(11);
        let inputs = grad_inputs(&params, b);
        let mut be = backend();
        let out = be.run("tiny/grad_b4_ls10", &inputs).unwrap();

        let loss_at = |be: &mut ReferenceBackend, tweaked: &[HostTensor]| -> f32 {
            let mut inp = tweaked.to_vec();
            inp.extend_from_slice(&inputs[N_PARAMS..]);
            be.run("tiny/grad_b4_ls10", &inp).unwrap()[0].scalar().unwrap()
        };

        let mut checked = 0usize;
        for &pi in &[P_STEM_W, P_BLOCK0, P_BLOCK0 + 4, P_BLOCK0 + 2, P_HEAD_W] {
            let g = out[1 + pi].as_f32().unwrap();
            let (ci, gmax) = g
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap();
            if gmax.abs() < 5e-3 {
                continue; // too small to resolve in f32 central differences
            }
            let h = 1e-3f32;
            let mut plus = params.clone();
            let mut minus = params.clone();
            plus[pi].as_f32_mut().unwrap()[ci] += h;
            minus[pi].as_f32_mut().unwrap()[ci] -= h;
            let fd = (loss_at(&mut be, &plus) - loss_at(&mut be, &minus)) / (2.0 * h);
            assert!(
                (fd - gmax).abs() <= 0.15 * gmax.abs().max(5e-3),
                "param {pi} coord {ci}: analytic {gmax} vs finite-diff {fd}"
            );
            checked += 1;
        }
        assert!(checked >= 3, "only {checked} tensors had resolvable grads");
    }

    #[test]
    fn apply_is_the_lars_reference_step() {
        let params = init_params(5);
        let mut be = backend();
        let grad_out = be.run("tiny/grad_b8_ls10", &grad_inputs(&params, 8)).unwrap();
        let grads = &grad_out[1..1 + N_PARAMS];
        let momenta: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
            .collect();
        let mut ap_in = params.clone();
        ap_in.extend(momenta.iter().cloned());
        ap_in.extend(grads.iter().cloned());
        ap_in.push(HostTensor::scalar_f32(0.5));
        ap_in.push(HostTensor::scalar_f32(0.9));
        ap_in.push(HostTensor::scalar_f32(5e-5));
        let applied = be.run("tiny/apply", &ap_in).unwrap();
        assert_eq!(applied.len(), 2 * N_PARAMS);
        // must agree with a direct lars_step on tensor 0
        let mut w_ref = params[0].as_f32().unwrap().to_vec();
        let mut m_ref = vec![0.0f32; w_ref.len()];
        let cfg = LarsConfig {
            coeff: 0.01,
            eps: 1e-6,
            weight_decay: 5e-5,
        };
        lars_step(
            &mut w_ref,
            grads[0].as_f32().unwrap(),
            &mut m_ref,
            0.5,
            0.9,
            &cfg,
        );
        assert_eq!(applied[0].as_f32().unwrap(), w_ref.as_slice());
        assert_ne!(applied[0], params[0], "update must move the weights");
    }

    #[test]
    fn descent_direction_reduces_loss() {
        let b = 8usize;
        let params = init_params(9);
        let inputs = grad_inputs(&params, b);
        let mut be = backend();
        let out = be.run("tiny/grad_b8_ls10", &inputs).unwrap();
        let loss0 = out[0].scalar().unwrap();
        // one small LARS step along the gradients
        let momenta: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
            .collect();
        let mut ap_in = params.clone();
        ap_in.extend(momenta);
        ap_in.extend(out[1..1 + N_PARAMS].iter().cloned());
        ap_in.push(HostTensor::scalar_f32(0.1));
        ap_in.push(HostTensor::scalar_f32(0.0));
        ap_in.push(HostTensor::scalar_f32(0.0));
        let applied = be.run("tiny/apply", &ap_in).unwrap();
        let mut inp2 = applied[..N_PARAMS].to_vec();
        inp2.extend_from_slice(&inputs[N_PARAMS..]);
        let loss1 = be.run("tiny/grad_b8_ls10", &inp2).unwrap()[0].scalar().unwrap();
        assert!(loss1 < loss0, "loss {loss0} -> {loss1}");
    }

    #[test]
    fn eval_reports_sane_loss_and_accuracy() {
        let params = init_params(3);
        let mut be = backend();
        // bn_running from one grad call's batch statistics
        let out = be.run("tiny/grad_b64_ls10", &grad_inputs(&params, 64)).unwrap();
        let stats = &out[1 + N_PARAMS..];
        let (images, labels) = sample_batch(EVAL_BATCH, 17);
        let mut ev_in = params.clone();
        ev_in.extend(stats.iter().cloned());
        ev_in.push(HostTensor::f32(vec![EVAL_BATCH, IMG, IMG, CH], images));
        ev_in.push(HostTensor::i32(vec![EVAL_BATCH], labels));
        let ev = be.run("tiny/eval_b64", &ev_in).unwrap();
        let loss = ev[0].scalar().unwrap() / EVAL_BATCH as f32;
        let correct = ev[1].scalar().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "{loss}");
        assert!((0.0..=EVAL_BATCH as f32).contains(&correct), "{correct}");
    }

    #[test]
    fn wrong_arity_and_shape_fail_fast() {
        let mut be = backend();
        assert!(be.run("tiny/init", &[]).is_err());
        assert!(be
            .run("tiny/init", &[HostTensor::f32(vec![1], vec![0.0])])
            .is_err());
        assert!(be.run("tiny/unknown", &[]).is_err());
        assert!(be.run("nope/init", &[]).is_err());
        assert!(be.run("badkey", &[]).is_err());
    }

    /// The resident-state session path must be bit-identical to the old
    /// stateless path: k steps of `grad_step` + in-place `apply` end with
    /// exactly the params/momenta that k steps of the `run`-based
    /// clone-everything loop produce.
    #[test]
    fn session_path_matches_stateless_path_bitwise() {
        let b = 8usize;
        let hp = ApplyParams {
            lr: 0.3,
            momentum: 0.9,
            weight_decay: 5e-5,
        };

        // stateless: params/momenta live caller-side, full clones per step
        let mut be_a = backend();
        let mut params = init_params(21);
        let mut momenta: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
            .collect();
        for step in 0..4u64 {
            let (images, labels) = sample_batch(b, 100 + step);
            let mut inputs = params.clone();
            inputs.push(HostTensor::f32(vec![b, IMG, IMG, CH], images));
            inputs.push(HostTensor::i32(vec![b], labels));
            let out = be_a.run("tiny/grad_b8_ls10", &inputs).unwrap();
            let mut ap_in = params.clone();
            ap_in.extend(momenta.iter().cloned());
            ap_in.extend(out[1..1 + N_PARAMS].iter().cloned());
            ap_in.push(HostTensor::scalar_f32(hp.lr));
            ap_in.push(HostTensor::scalar_f32(hp.momentum));
            ap_in.push(HostTensor::scalar_f32(hp.weight_decay));
            let applied = be_a.run("tiny/apply", &ap_in).unwrap();
            momenta = applied[N_PARAMS..].to_vec();
            params = applied[..N_PARAMS].to_vec();
        }

        // session: params/momenta resident, only batches + grads move
        let mut be_b = backend();
        let sid = be_b.create_state("tiny", 21).unwrap();
        for step in 0..4u64 {
            let (images, labels) = sample_batch(b, 100 + step);
            let img = HostTensor::f32(vec![b, IMG, IMG, CH], images);
            let lab = HostTensor::i32(vec![b], labels);
            let out = be_b.grad_step(sid, "grad_b8_ls10", &img, &lab).unwrap();
            be_b.apply(sid, &out[1..1 + N_PARAMS], hp).unwrap();
        }
        let (sp, sm) = be_b.export_state(sid).unwrap();
        assert_eq!(sp, params, "params diverged from the stateless path");
        assert_eq!(sm, momenta, "momenta diverged from the stateless path");
    }

    /// export → import (onto a *different* backend instance) → export must
    /// round-trip byte-identically — the phase-handoff invariant under BSC
    /// worker-count changes.
    #[test]
    fn export_import_round_trips_bitwise() {
        let mut be_a = backend();
        let sid = be_a.create_state("tiny", 5).unwrap();
        let (images, labels) = sample_batch(8, 9);
        let img = HostTensor::f32(vec![8, IMG, IMG, CH], images);
        let lab = HostTensor::i32(vec![8], labels);
        let out = be_a.grad_step(sid, "grad_b8_ls10", &img, &lab).unwrap();
        be_a.apply(
            sid,
            &out[1..1 + N_PARAMS],
            ApplyParams {
                lr: 0.5,
                momentum: 0.9,
                weight_decay: 5e-5,
            },
        )
        .unwrap();
        let (p1, m1) = be_a.export_state(sid).unwrap();

        let mut be_b = backend();
        let sid2 = be_b.import_state("tiny", p1.clone(), m1.clone()).unwrap();
        let (p2, m2) = be_b.export_state(sid2).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);

        // export moves the state out: both handles are now dead
        assert!(be_a.export_state(sid).is_err());
        assert!(be_a.drop_state(sid).is_err());
        assert!(be_b.export_state(sid2).is_err());

        // drop_state releases without reading back
        let sid3 = be_b.import_state("tiny", p1, m1).unwrap();
        be_b.drop_state(sid3).unwrap();
        assert!(be_b.export_state(sid3).is_err());
    }

    #[test]
    fn session_rejects_bad_inputs() {
        let mut be = backend();
        let sid = be.create_state("tiny", 1).unwrap();
        let img = HostTensor::f32(vec![8, IMG, IMG, CH], vec![0.0; 8 * IN]);
        let lab = HostTensor::i32(vec![8], vec![0; 8]);
        // wrong exec family
        assert!(be.grad_step(sid, "apply", &img, &lab).is_err());
        // batch mismatch between exec and tensors
        assert!(be.grad_step(sid, "grad_b16_ls10", &img, &lab).is_err());
        // unknown state id
        assert!(be.grad_step(sid + 999, "grad_b8_ls10", &img, &lab).is_err());
        // wrong momenta arity on import
        assert!(be.import_state("tiny", init_params(1), vec![]).is_err());
    }

    /// The streaming grad path must match the monolithic one bit for bit:
    /// same loss, same BN stats, every gradient identical — delivered in
    /// strictly decreasing parameter order, exactly once each.
    #[test]
    fn streaming_grad_matches_monolithic_bitwise() {
        let mut be = backend();
        let sid = be.create_state("tiny", 3).unwrap();
        let (images, labels) = sample_batch(8, 17);
        let img = HostTensor::f32(vec![8, IMG, IMG, CH], images);
        let lab = HostTensor::i32(vec![8], labels);

        let full = be.grad_step(sid, "grad_b8_ls10", &img, &lab).unwrap();
        let mut emitted: Vec<(usize, HostTensor)> = Vec::new();
        let outs = be
            .grad_step_streaming(sid, "grad_b8_ls10", &img, &lab, &mut |i, t| {
                emitted.push((i, t))
            })
            .unwrap();

        assert_eq!(outs.len(), 1 + N_BN, "streaming returns [loss, bn..] only");
        assert_eq!(outs[0], full[0], "loss must match");
        assert_eq!(&outs[1..], &full[1 + N_PARAMS..], "bn stats must match");
        assert_eq!(emitted.len(), N_PARAMS);
        assert!(
            emitted.windows(2).all(|w| w[0].0 > w[1].0),
            "emission order must be strictly decreasing param index: {:?}",
            emitted.iter().map(|(i, _)| *i).collect::<Vec<_>>()
        );
        for (i, t) in &emitted {
            assert_eq!(t, &full[1 + i], "gradient #{i} diverged");
        }
    }

    /// Per-bucket `apply_partial` (any contiguous partition, any bucket
    /// order) must be bit-identical to one whole-model `apply` — the LARS
    /// trust ratio is per-tensor, so the partition cannot change numerics.
    #[test]
    fn apply_partial_matches_whole_model_apply_bitwise() {
        let hp = ApplyParams {
            lr: 0.4,
            momentum: 0.9,
            weight_decay: 5e-5,
        };
        let mut be = backend();
        let s_full = be.create_state("tiny", 6).unwrap();
        let s_part = be.create_state("tiny", 6).unwrap();
        let (images, labels) = sample_batch(8, 23);
        let img = HostTensor::f32(vec![8, IMG, IMG, CH], images);
        let lab = HostTensor::i32(vec![8], labels);
        let out = be.grad_step(s_full, "grad_b8_ls10", &img, &lab).unwrap();
        let grads = &out[1..1 + N_PARAMS];

        be.apply(s_full, grads, hp).unwrap();
        // uneven tensor-aligned partition, applied out of order
        let cuts = [0usize, 2, 7, 20, N_PARAMS];
        for w in cuts.windows(2).rev() {
            be.apply_partial(s_part, w[0], grads[w[0]..w[1]].to_vec(), hp)
                .unwrap();
        }

        let (pf, mf) = be.export_state(s_full).unwrap();
        let (pp, mp) = be.export_state(s_part).unwrap();
        assert_eq!(pf, pp, "bucketed apply changed the parameters");
        assert_eq!(mf, mp, "bucketed apply changed the momenta");

        // out-of-range slice is rejected
        let s = be.create_state("tiny", 1).unwrap();
        assert!(be
            .apply_partial(s, N_PARAMS - 1, grads[..2].to_vec(), hp)
            .is_err());
    }
}
