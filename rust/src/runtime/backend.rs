//! The compute-backend abstraction: who actually runs `init` / `grad_*` /
//! `apply` / `eval_*`.
//!
//! The coordinator is backend-agnostic: workers submit
//! `("{arch}/{exec}", host tensors)` calls through
//! [`super::service::ComputeClient`] and the service thread dispatches them
//! to whichever [`ComputeBackend`] the run was started with:
//!
//! * [`super::reference::ReferenceBackend`] (default) — a pure-Rust dense
//!   forward/backward for the built-in `tiny` arch. No Python, no
//!   artifacts, no XLA: the whole training stack runs and is tested from a
//!   clean checkout.
//! * `runtime::engine::PjrtBackend` (`--features pjrt`) — compiles AOT HLO
//!   artifacts through the PJRT C API (`xla` crate) as lowered by
//!   `python/compile/aot.py`.
//!
//! Backends may be thread-confined (PJRT clients are `Rc`-based), so they
//! are constructed *inside* the service thread from a [`BackendSpec`],
//! which is the `Send` handle the coordinator passes around.

use anyhow::Result;

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// An executor of manifest-declared executables.
///
/// Keys use the `"{arch}/{exec}"` form everywhere (the same naming the
/// artifact pipeline uses), and implementations validate inputs against the
/// manifest's tensor specs so a caller bug fails fast with shapes in the
/// message.
pub trait ComputeBackend {
    /// Short backend name for logs and error messages.
    fn name(&self) -> &'static str;

    /// Make `names` of `arch` executable (compile artifacts, or validate
    /// that the built-in model serves them). Batch-size control calls this
    /// lazily when a phase needs a grad variant that was not preloaded.
    fn load(&mut self, arch: &str, names: &[&str]) -> Result<()>;

    /// Execute `key` with host inputs; returns host outputs.
    fn run(&mut self, key: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// Which backend a run should use. `Send`-able recipe; the backend itself
/// is built on the service thread via [`BackendSpec::instantiate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendSpec {
    /// Pure-Rust reference backend (default features).
    Reference,
    /// PJRT/XLA over AOT artifacts (requires `--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendSpec {
    /// Construct the backend over `manifest`. Must run on the thread that
    /// will own the backend (PJRT clients cannot migrate threads).
    pub fn instantiate(self, manifest: Manifest) -> Result<Box<dyn ComputeBackend>> {
        match self {
            BackendSpec::Reference => Ok(Box::new(super::reference::ReferenceBackend::new(
                manifest,
            )?)),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => Ok(Box::new(super::engine::PjrtBackend::new(manifest)?)),
        }
    }
}
