//! The compute-backend abstraction: who actually runs `init` / `grad_*` /
//! `apply` / `eval_*`, and where the training state lives between steps.
//!
//! The coordinator is backend-agnostic: workers drive a lane of the
//! [`super::service::ComputeService`] pool through
//! [`super::service::ComputeClient`], and each lane thread dispatches to its
//! own [`ComputeBackend`] instance:
//!
//! * [`super::reference::ReferenceBackend`] (default) — a pure-Rust dense
//!   forward/backward for the built-in `tiny` arch. No Python, no
//!   artifacts, no XLA: the whole training stack runs and is tested from a
//!   clean checkout.
//! * `runtime::engine::PjrtBackend` (`--features pjrt`) — compiles AOT HLO
//!   artifacts through the PJRT C API (`xla` crate) as lowered by
//!   `python/compile/aot.py`.
//!
//! Backends may be thread-confined (PJRT clients are `Rc`-based), so they
//! are constructed *inside* each lane thread from a [`BackendSpec`], which
//! is the `Send` handle the coordinator passes around.
//!
//! ## Resident state
//!
//! A backend owns **resident training state**: `(params, momenta)` pairs
//! registered through [`ComputeBackend::import_state`] (or created fresh
//! with [`ComputeBackend::create_state`]) and addressed by an opaque
//! [`StateId`]. The steady-state training step is then
//! [`ComputeBackend::grad_step`] (ships a batch in, gets loss + grads + BN
//! stats out) followed by [`ComputeBackend::apply`] (ships the reduced
//! gradient and three scalars in, updates the resident params/momenta in
//! place) — the full parameter set never crosses the channel boundary
//! during a phase. The coordinator pulls state out with
//! [`ComputeBackend::export_state`] only at phase boundaries (replica
//! bit-identity check, BSC worker-count changes, checkpointing).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Opaque handle to one resident `(params, momenta)` pair inside a backend.
pub type StateId = u64;

/// The three scalars of the LARS `apply` entry point.
#[derive(Debug, Clone, Copy)]
pub struct ApplyParams {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
}

/// One resident training state (per-rank device state in the simulated
/// cluster: parameters + optimizer momenta, replicated across ranks).
#[derive(Debug, Clone)]
pub struct ResidentState {
    /// Architecture this state belongs to (validates exec dispatch).
    pub arch: String,
    pub params: Vec<HostTensor>,
    pub momenta: Vec<HostTensor>,
}

/// Id-keyed table of resident states; shared bookkeeping for backends.
#[derive(Debug, Default)]
pub struct StateTable {
    next: StateId,
    states: HashMap<StateId, ResidentState>,
}

impl StateTable {
    pub fn insert(&mut self, state: ResidentState) -> StateId {
        let id = self.next;
        self.next += 1;
        self.states.insert(id, state);
        id
    }

    pub fn get(&self, id: StateId) -> Result<&ResidentState> {
        self.states
            .get(&id)
            .ok_or_else(|| anyhow!("no resident state {id} (dropped or never created?)"))
    }

    pub fn get_mut(&mut self, id: StateId) -> Result<&mut ResidentState> {
        self.states
            .get_mut(&id)
            .ok_or_else(|| anyhow!("no resident state {id} (dropped or never created?)"))
    }

    pub fn remove(&mut self, id: StateId) -> Result<ResidentState> {
        self.states
            .remove(&id)
            .ok_or_else(|| anyhow!("no resident state {id} (dropped or never created?)"))
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }
}

/// An executor of manifest-declared executables plus resident per-rank
/// training state.
///
/// Keys use the `"{arch}/{exec}"` form everywhere (the same naming the
/// artifact pipeline uses), and implementations validate inputs against the
/// manifest's tensor specs so a caller bug fails fast with shapes in the
/// message. The session methods take a bare exec name (e.g.
/// `"grad_b8_ls10"`) — the arch is fixed at state creation.
pub trait ComputeBackend {
    /// Short backend name for logs and error messages.
    fn name(&self) -> &'static str;

    /// Make `names` of `arch` executable (compile artifacts, or validate
    /// that the built-in model serves them). Batch-size control calls this
    /// lazily when a phase needs a grad variant that was not preloaded.
    fn load(&mut self, arch: &str, names: &[&str]) -> Result<()>;

    /// Execute `key` with host inputs; returns host outputs. Stateless
    /// entry points (`init`, `eval_*`) and compatibility path for callers
    /// that keep the state themselves.
    fn run(&mut self, key: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;

    // --- session/state API -------------------------------------------------

    /// Create a fresh resident state: `init(seed)` parameters, zero
    /// momenta. Returns its handle.
    fn create_state(&mut self, arch: &str, seed: i32) -> Result<StateId>;

    /// Register an existing `(params, momenta)` pair as resident state
    /// (phase handoff, checkpoint resume). Tensors are validated against
    /// the manifest's parameter table.
    fn import_state(
        &mut self,
        arch: &str,
        params: Vec<HostTensor>,
        momenta: Vec<HostTensor>,
    ) -> Result<StateId>;

    /// **Move** a resident state out: `(params, momenta)`. The handle
    /// becomes invalid — import the tensors again to continue training (a
    /// phase boundary does exactly that). By-move keeps the phase-exit
    /// handoff zero-copy on the backend side, and the round trip is
    /// bit-exact: `import_state` → `export_state` yields identical bytes.
    fn export_state(&mut self, state: StateId) -> Result<(Vec<HostTensor>, Vec<HostTensor>)>;

    /// Release a resident state without reading it back.
    fn drop_state(&mut self, state: StateId) -> Result<()>;

    /// One local gradient computation against the resident parameters:
    /// returns `[loss, grads.., bn_stats..]` exactly like the stateless
    /// `grad_b{B}_ls{S}` executable, without shipping the parameters.
    fn grad_step(
        &mut self,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<Vec<HostTensor>>;

    /// Streaming variant of [`Self::grad_step`]: identical numerics, but
    /// each parameter gradient is handed to `emit(param_index, grad)` the
    /// moment the backward pass finalises it — **strictly decreasing
    /// parameter index**, i.e. reverse layer order, exactly once per
    /// parameter — and only `[loss, bn_stats..]` comes back in the return
    /// value. This is what lets the caller all-reduce early buckets while
    /// the backend is still producing later ones (paper §2.2 overlap).
    ///
    /// Backends that execute a monolithic grad program (the AOT/PJRT
    /// path) may run it whole and emit post-hoc in the same order; the
    /// contract is only about ordering and exactly-once delivery.
    fn grad_step_streaming(
        &mut self,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
        emit: &mut dyn FnMut(usize, HostTensor),
    ) -> Result<Vec<HostTensor>>;

    /// LARS update of the resident `(params, momenta)` in place from the
    /// reduced gradients and the step's `(lr, momentum, weight_decay)`.
    fn apply(&mut self, state: StateId, grads: &[HostTensor], hp: ApplyParams) -> Result<()>;

    /// LARS update of a **contiguous slice** of the resident parameters:
    /// `grads[i]` updates parameter `first_param + i`. LARS trust ratios
    /// are per-tensor, so applying the model bucket by bucket (in any
    /// bucket order, each parameter exactly once per step with the same
    /// `hp`) is bit-identical to one whole-model [`Self::apply`] — the
    /// per-bucket leg of the overlapped reduction pipeline. Takes the
    /// gradients by value so backends that must stage buckets (the
    /// whole-model AOT apply path) can keep them without cloning.
    fn apply_partial(
        &mut self,
        state: StateId,
        first_param: usize,
        grads: Vec<HostTensor>,
        hp: ApplyParams,
    ) -> Result<()>;

    /// Evaluation forward pass against the resident parameters with the
    /// caller's synchronized running BN statistics: returns the `eval_b{B}`
    /// outputs (`[loss_sum, n_correct]`).
    fn eval_step(
        &mut self,
        state: StateId,
        exec: &str,
        bn_running: &[HostTensor],
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<Vec<HostTensor>>;
}

/// Validate an imported `(params, momenta)` pair against `manifest`'s
/// parameter table for `arch`; shared by backend implementations.
pub fn check_state_tensors(
    manifest: &Manifest,
    arch: &str,
    params: &[HostTensor],
    momenta: &[HostTensor],
) -> Result<()> {
    let am = manifest.arch(arch)?;
    if params.len() != am.n_params() || momenta.len() != am.n_params() {
        bail!(
            "import_state({arch}): got {} params / {} momenta, manifest says {}",
            params.len(),
            momenta.len(),
            am.n_params()
        );
    }
    for (kind, tensors) in [("param", params), ("momentum", momenta)] {
        for (i, (t, spec)) in tensors.iter().zip(&am.params).enumerate() {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "import_state({arch}): {kind} #{i} ({}) has shape {:?}, manifest says {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            // fail fast with the param name here, not steps later inside
            // grad_step/apply with a bare dtype-conversion error
            if t.as_f32().is_err() {
                bail!(
                    "import_state({arch}): {kind} #{i} ({}) is not an f32 tensor",
                    spec.name
                );
            }
        }
    }
    Ok(())
}

/// Which backend a run should use. `Send`-able recipe; the backend itself
/// is built on each lane thread via [`BackendSpec::instantiate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendSpec {
    /// Pure-Rust reference backend (default features).
    Reference,
    /// PJRT/XLA over AOT artifacts (requires `--features pjrt`).
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl BackendSpec {
    /// Construct the backend over `manifest`. Must run on the thread that
    /// will own the backend (PJRT clients cannot migrate threads).
    pub fn instantiate(self, manifest: Manifest) -> Result<Box<dyn ComputeBackend>> {
        match self {
            BackendSpec::Reference => Ok(Box::new(super::reference::ReferenceBackend::new(
                manifest,
            )?)),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt => Ok(Box::new(super::engine::PjrtBackend::new(manifest)?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_table_insert_get_remove() {
        let mut t = StateTable::default();
        let a = t.insert(ResidentState {
            arch: "tiny".into(),
            params: vec![HostTensor::scalar_f32(1.0)],
            momenta: vec![HostTensor::scalar_f32(0.0)],
        });
        let b = t.insert(ResidentState {
            arch: "tiny".into(),
            params: vec![HostTensor::scalar_f32(2.0)],
            momenta: vec![HostTensor::scalar_f32(0.0)],
        });
        assert_ne!(a, b);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(a).unwrap().params[0].scalar().unwrap(), 1.0);
        t.get_mut(b).unwrap().params[0] = HostTensor::scalar_f32(3.0);
        assert_eq!(t.get(b).unwrap().params[0].scalar().unwrap(), 3.0);
        let removed = t.remove(a).unwrap();
        assert_eq!(removed.params[0].scalar().unwrap(), 1.0);
        assert!(t.get(a).is_err());
        assert!(t.remove(a).is_err());
        assert_eq!(t.len(), 1);
    }
}
