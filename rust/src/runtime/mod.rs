//! Runtime layer: pluggable compute backends behind one multi-lane pool.
//!
//! `manifest` is the shape/layout contract every backend serves (parsed
//! from `aot.py`'s `manifest.json`, or synthesized in memory by the
//! reference backend); `backend` defines the [`ComputeBackend`] trait —
//! stateless executables *plus* the resident-state session API
//! (`create_state` / `import_state` / `grad_step` / `apply` / `eval_step` /
//! `export_state`) — and the [`BackendSpec`] used to pick an
//! implementation; `reference` is the default pure-Rust backend; `engine`
//! (behind `--features pjrt`) compiles HLO text and executes it on the PJRT
//! CPU client; `service` runs one backend instance per **lane** thread so
//! ranks compute concurrently, with each rank's `(params, momenta)`
//! resident in its lane; `tensor` is the `Send`-able host-buffer currency.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod reference;
pub mod service;
pub mod tensor;

pub use backend::{ApplyParams, BackendSpec, ComputeBackend, ResidentState, StateId, StateTable};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, PjrtBackend};
pub use manifest::{ArchManifest, BnLayer, Dtype, ExecSpec, Manifest, ParamSpec, TensorSpec};
pub use reference::{builtin_manifest, ReferenceBackend};
pub use service::{ComputeClient, ComputeService, GradStream, Pending, PoolStats, StateRef};
pub use tensor::HostTensor;
