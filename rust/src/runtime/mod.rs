//! Runtime layer: AOT artifact loading + PJRT execution.
//!
//! `manifest` parses the shape/layout contract written by `aot.py`;
//! `engine` compiles HLO text and executes it on the PJRT CPU client;
//! `service` exposes the (thread-confined) engine to the coordinator's
//! worker threads; `tensor` is the `Send`-able host-buffer currency.

pub mod engine;
pub mod manifest;
pub mod service;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{ArchManifest, BnLayer, Dtype, ExecSpec, Manifest, ParamSpec, TensorSpec};
pub use service::{ComputeClient, ComputeService};
pub use tensor::HostTensor;
