//! Runtime layer: pluggable compute backends behind one service API.
//!
//! `manifest` is the shape/layout contract every backend serves (parsed
//! from `aot.py`'s `manifest.json`, or synthesized in memory by the
//! reference backend); `backend` defines the [`ComputeBackend`] trait and
//! the [`BackendSpec`] used to pick an implementation; `reference` is the
//! default pure-Rust backend; `engine` (behind `--features pjrt`) compiles
//! HLO text and executes it on the PJRT CPU client; `service` exposes the
//! (thread-confined) backend to the coordinator's worker threads; `tensor`
//! is the `Send`-able host-buffer currency.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod reference;
pub mod service;
pub mod tensor;

pub use backend::{BackendSpec, ComputeBackend};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, PjrtBackend};
pub use manifest::{ArchManifest, BnLayer, Dtype, ExecSpec, Manifest, ParamSpec, TensorSpec};
pub use reference::{builtin_manifest, ReferenceBackend};
pub use service::{ComputeClient, ComputeService};
pub use tensor::HostTensor;
