//! PJRT engine: load AOT HLO-text artifacts, compile, execute.
//!
//! Compiled only with `--features pjrt`; the default build uses
//! `runtime::reference::ReferenceBackend` instead. [`PjrtBackend`] adapts
//! the engine to the `runtime::backend::ComputeBackend` trait the service
//! thread dispatches on. Note the workspace vendors an API *stub* of the
//! `xla` crate, so `--features pjrt` compiles everywhere but only runs
//! when the real crate is swapped in.
//!
//! Follows the reference wiring in /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Every entry point was lowered with
//! `return_tuple=True`, so outputs arrive as one tuple literal that we
//! split back into per-tensor host buffers.
//!
//! The engine is thread-confined (`PjRtClient` holds an `Rc`); worker
//! threads reach it through `runtime::service::ComputeService`.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{
    check_state_tensors, ApplyParams, ResidentState, StateId, StateTable,
};
use super::manifest::{ArchManifest, Dtype, ExecSpec, Manifest};
use super::tensor::HostTensor;

/// One compiled executable plus its manifest spec.
pub struct Compiled {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT engine: a CPU client and a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            compiled: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text file under key `name`.
    pub fn load_hlo(&mut self, name: &str, path: &Path, spec: ExecSpec) -> Result<()> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {name} ({path:?}): {e}"))?;
        self.compiled.insert(name.to_string(), Compiled { spec, exe });
        Ok(())
    }

    /// Compile every executable of `arch` from the manifest.
    pub fn load_arch(&mut self, manifest: &Manifest, arch: &ArchManifest) -> Result<()> {
        for (name, spec) in &arch.executables {
            let key = format!("{}/{}", arch.name, name);
            if self.compiled.contains_key(&key) {
                continue;
            }
            self.load_hlo(&key, &manifest.hlo_path(spec), spec.clone())
                .with_context(|| format!("loading {key}"))?;
        }
        Ok(())
    }

    /// Compile a subset of `arch`'s executables (lazy startup).
    pub fn load_execs(
        &mut self,
        manifest: &Manifest,
        arch: &ArchManifest,
        names: &[&str],
    ) -> Result<()> {
        for name in names {
            let key = format!("{}/{}", arch.name, name);
            if self.compiled.contains_key(&key) {
                continue;
            }
            let spec = arch.exec(name)?;
            self.load_hlo(&key, &manifest.hlo_path(spec), spec.clone())
                .with_context(|| format!("loading {key}"))?;
        }
        Ok(())
    }

    pub fn has(&self, key: &str) -> bool {
        self.compiled.contains_key(key)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    /// Execute `key` with host inputs; returns host outputs.
    ///
    /// Inputs are validated against the manifest spec — a mismatch is a
    /// caller bug and fails fast with tensor index + expected shape.
    pub fn run(&self, key: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let c = self
            .compiled
            .get(key)
            .ok_or_else(|| anyhow!("executable {key:?} not loaded (have {:?})", self.loaded()))?;
        if inputs.len() != c.spec.inputs.len() {
            bail!(
                "{key}: wrong input arity {} (want {})",
                inputs.len(),
                c.spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&c.spec.inputs).enumerate() {
            t.check(s).with_context(|| format!("{key}: input #{i}"))?;
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = c
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{key}: execute failed: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{key}: readback failed: {e}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("{key}: output is not a tuple: {e}"))?;
        if parts.len() != c.spec.outputs.len() {
            bail!(
                "{key}: output arity {} (manifest says {})",
                parts.len(),
                c.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&c.spec.outputs)
            .enumerate()
            .map(|(i, (lit, spec))| {
                from_literal(&lit, spec.dtype, &spec.shape)
                    .with_context(|| format!("{key}: output #{i}"))
            })
            .collect()
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, &[u8]) = match t {
        HostTensor::F32 { data, .. } => (xla::ElementType::F32, bytemuck_f32(data)),
        HostTensor::I32 { data, .. } => (xla::ElementType::S32, bytemuck_i32(data)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, t.shape(), bytes)
        .map_err(|e| anyhow!("literal creation: {e}"))
}

fn from_literal(lit: &xla::Literal, dtype: Dtype, shape: &[usize]) -> Result<HostTensor> {
    Ok(match dtype {
        Dtype::F32 => HostTensor::f32(
            shape.to_vec(),
            lit.to_vec::<f32>().map_err(|e| anyhow!("readback f32: {e}"))?,
        ),
        Dtype::I32 => HostTensor::i32(
            shape.to_vec(),
            lit.to_vec::<i32>().map_err(|e| anyhow!("readback i32: {e}"))?,
        ),
    })
}

/// [`ComputeBackend`](super::backend::ComputeBackend) adapter over the
/// PJRT [`Engine`]: owns the engine plus the manifest it compiles from.
///
/// The session/state API keeps `(params, momenta)` host-side in a
/// [`StateTable`] and composes the stateless executables — the device
/// round trip stays inside one lane thread, so the coordinator still never
/// ships parameters during a phase. (A future device-resident variant
/// would hold `PjRtBuffer`s here instead.)
pub struct PjrtBackend {
    engine: Engine,
    manifest: Manifest,
    states: StateTable,
    /// Per-state staging for `apply_partial`: the AOT `apply` executable
    /// is whole-model, so bucket updates are coalesced here and the real
    /// apply runs once the last bucket lands — bit-identical to a single
    /// whole-model apply (the buckets partition the parameter table).
    partial: HashMap<StateId, Vec<Option<HostTensor>>>,
}

impl PjrtBackend {
    /// Create a CPU PJRT client for `manifest`'s artifacts.
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(Self {
            engine: Engine::cpu()?,
            manifest,
            states: StateTable::default(),
            partial: HashMap::new(),
        })
    }
}

impl super::backend::ComputeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&mut self, arch: &str, names: &[&str]) -> Result<()> {
        let am = self.manifest.arch(arch)?.clone();
        self.engine.load_execs(&self.manifest, &am, names)
    }

    fn run(&mut self, key: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.engine.run(key, inputs)
    }

    fn create_state(&mut self, arch: &str, seed: i32) -> Result<StateId> {
        let am = self.manifest.arch(arch)?.clone();
        self.load(arch, &["init"])?;
        let key = format!("{arch}/init");
        let params = self
            .engine
            .run(&key, &[HostTensor::i32(vec![1], vec![seed])])?;
        if params.len() != am.n_params() {
            bail!(
                "{key}: produced {} tensors, manifest says {}",
                params.len(),
                am.n_params()
            );
        }
        let momenta: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
            .collect();
        Ok(self.states.insert(ResidentState {
            arch: arch.to_string(),
            params,
            momenta,
        }))
    }

    fn import_state(
        &mut self,
        arch: &str,
        params: Vec<HostTensor>,
        momenta: Vec<HostTensor>,
    ) -> Result<StateId> {
        check_state_tensors(&self.manifest, arch, &params, &momenta)?;
        Ok(self.states.insert(ResidentState {
            arch: arch.to_string(),
            params,
            momenta,
        }))
    }

    fn export_state(&mut self, state: StateId) -> Result<(Vec<HostTensor>, Vec<HostTensor>)> {
        let st = self.states.remove(state)?;
        self.partial.remove(&state); // drop any half-delivered bucket set
        Ok((st.params, st.momenta))
    }

    fn drop_state(&mut self, state: StateId) -> Result<()> {
        self.partial.remove(&state);
        self.states.remove(state).map(|_| ())
    }

    fn grad_step(
        &mut self,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let st = self.states.get(state)?;
        let key = format!("{}/{exec}", st.arch);
        let mut inputs = st.params.clone();
        inputs.push(images.clone());
        inputs.push(labels.clone());
        self.engine.run(&key, &inputs)
    }

    fn grad_step_streaming(
        &mut self,
        state: StateId,
        exec: &str,
        images: &HostTensor,
        labels: &HostTensor,
        emit: &mut dyn FnMut(usize, HostTensor),
    ) -> Result<Vec<HostTensor>> {
        // The AOT grad program is monolithic, so this backend cannot
        // interleave emission with the backward pass; it satisfies the
        // streaming contract (strictly decreasing parameter index, exactly
        // once each) by running the program whole and emitting post-hoc.
        // A device-resident engine would hook per-layer donation here.
        let out = self.grad_step(state, exec, images, labels)?;
        let n = self.states.get(state)?.params.len();
        if out.len() < 1 + n {
            bail!(
                "grad_step_streaming({exec}): {} outputs for {n} params",
                out.len()
            );
        }
        let mut iter = out.into_iter();
        let loss = iter.next().expect("checked arity above");
        let mut grads: Vec<HostTensor> = iter.by_ref().take(n).collect();
        let rest: Vec<HostTensor> = iter.collect();
        for idx in (0..n).rev() {
            emit(idx, grads.pop().expect("one grad per param"));
        }
        let mut res = Vec::with_capacity(1 + rest.len());
        res.push(loss);
        res.extend(rest);
        Ok(res)
    }

    fn apply_partial(
        &mut self,
        state: StateId,
        first_param: usize,
        grads: Vec<HostTensor>,
        hp: ApplyParams,
    ) -> Result<()> {
        let n = self.states.get(state)?.params.len();
        if first_param + grads.len() > n {
            bail!(
                "apply_partial: params [{first_param}, {}) out of range (model has {n})",
                first_param + grads.len()
            );
        }
        let slots = self
            .partial
            .entry(state)
            .or_insert_with(|| vec![None; n]);
        for (i, g) in grads.into_iter().enumerate() {
            let slot = &mut slots[first_param + i];
            if slot.is_some() {
                bail!(
                    "apply_partial: param #{} delivered twice before the model completed",
                    first_param + i
                );
            }
            *slot = Some(g);
        }
        if slots.iter().all(|s| s.is_some()) {
            let full: Vec<HostTensor> = self
                .partial
                .remove(&state)
                .expect("entry exists")
                .into_iter()
                .map(|s| s.expect("all slots checked"))
                .collect();
            // All buckets of the step share one `hp`, so running the
            // whole-model executable now is the same update.
            self.apply(state, &full, hp)?;
        }
        Ok(())
    }

    fn apply(&mut self, state: StateId, grads: &[HostTensor], hp: ApplyParams) -> Result<()> {
        let st = self.states.get(state)?;
        let n = st.params.len();
        if grads.len() != n {
            bail!("apply: {} grads for {n} resident params", grads.len());
        }
        let key = format!("{}/apply", st.arch);
        let mut inputs = Vec::with_capacity(3 * n + 3);
        inputs.extend(st.params.iter().cloned());
        inputs.extend(st.momenta.iter().cloned());
        inputs.extend(grads.iter().cloned());
        inputs.push(HostTensor::scalar_f32(hp.lr));
        inputs.push(HostTensor::scalar_f32(hp.momentum));
        inputs.push(HostTensor::scalar_f32(hp.weight_decay));
        let out = self.engine.run(&key, &inputs)?;
        if out.len() != 2 * n {
            bail!("{key}: output arity {} (want {})", out.len(), 2 * n);
        }
        let st = self.states.get_mut(state)?;
        st.momenta = out[n..].to_vec();
        st.params = out[..n].to_vec();
        Ok(())
    }

    fn eval_step(
        &mut self,
        state: StateId,
        exec: &str,
        bn_running: &[HostTensor],
        images: &HostTensor,
        labels: &HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let st = self.states.get(state)?;
        let key = format!("{}/{exec}", st.arch);
        let mut inputs = st.params.clone();
        inputs.extend(bn_running.iter().cloned());
        inputs.push(images.clone());
        inputs.push(labels.clone());
        self.engine.run(&key, &inputs)
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    // Safety: f32 has no padding; alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    // Safety: i32 has no padding; alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    fn manifest() -> Option<Manifest> {
        Manifest::load(ARTIFACTS).ok()
    }

    #[test]
    fn init_grad_apply_round_trip() {
        let Some(m) = manifest() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let arch = m.arch("tiny").unwrap().clone();
        let mut eng = Engine::cpu().unwrap();
        eng.load_execs(&m, &arch, &["init", "grad_b8_ls10", "apply"])
            .unwrap();

        // init: seed -> params
        let params = eng
            .run("tiny/init", &[HostTensor::i32(vec![1], vec![7])])
            .unwrap();
        assert_eq!(params.len(), arch.n_params());
        let total: usize = params.iter().map(|p| p.elems()).sum();
        assert_eq!(total, arch.total_params);

        // grad: params + batch -> loss, grads, bn stats
        let px = arch.image_size * arch.image_size * arch.image_channels;
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(
            vec![8, arch.image_size, arch.image_size, arch.image_channels],
            vec![0.1; 8 * px],
        ));
        inputs.push(HostTensor::i32(vec![8], vec![0, 1, 2, 3, 4, 5, 6, 7]));
        let out = eng.run("tiny/grad_b8_ls10", &inputs).unwrap();
        assert_eq!(out.len(), 1 + arch.n_params() + arch.n_bn());
        let loss = out[0].scalar().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

        // apply: params + momenta + grads + scalars -> params', momenta'
        let grads = &out[1..1 + arch.n_params()];
        let momenta: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.elems()]))
            .collect();
        let mut ap_in = params.clone();
        ap_in.extend(momenta.iter().cloned());
        ap_in.extend(grads.iter().cloned());
        ap_in.push(HostTensor::scalar_f32(0.5));
        ap_in.push(HostTensor::scalar_f32(0.9));
        ap_in.push(HostTensor::scalar_f32(5e-5));
        let applied = eng.run("tiny/apply", &ap_in).unwrap();
        assert_eq!(applied.len(), 2 * arch.n_params());

        // the update must actually move the weights
        let before = params[0].as_f32().unwrap();
        let after = applied[0].as_f32().unwrap();
        assert_ne!(before, after);

        // and must agree with the rust LARS reference (same formula)
        let mut w_ref = before.to_vec();
        let mut m_ref = vec![0.0f32; w_ref.len()];
        let cfg = crate::optim::LarsConfig {
            coeff: 0.01,
            eps: 1e-6,
            weight_decay: 5e-5,
        };
        crate::optim::lars_step(
            &mut w_ref,
            grads[0].as_f32().unwrap(),
            &mut m_ref,
            0.5,
            0.9,
            &cfg,
        );
        for (a, b) in after.iter().zip(&w_ref) {
            assert!((a - b).abs() < 2e-5, "pallas {a} vs rust-ref {b}");
        }
    }

    #[test]
    fn wrong_arity_and_shape_fail_fast() {
        let Some(m) = manifest() else { return };
        let arch = m.arch("tiny").unwrap().clone();
        let mut eng = Engine::cpu().unwrap();
        eng.load_execs(&m, &arch, &["init"]).unwrap();
        assert!(eng.run("tiny/init", &[]).is_err());
        assert!(eng
            .run("tiny/init", &[HostTensor::f32(vec![1], vec![0.0])])
            .is_err());
        assert!(eng.run("tiny/unknown", &[]).is_err());
    }
}
