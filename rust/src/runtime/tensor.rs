//! Host-side tensors: the `Send`-able data that crosses worker↔engine
//! channel boundaries (PJRT `Literal`s wrap raw C pointers and are not
//! `Send`; flat host buffers are).

use anyhow::{bail, Result};

use super::manifest::{Dtype, TensorSpec};

/// A flat host tensor (row-major) with shape.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        match spec.dtype {
            Dtype::F32 => HostTensor::f32(spec.shape.clone(), vec![0.0; spec.elems()]),
            Dtype::I32 => HostTensor::i32(spec.shape.clone(), vec![0; spec.elems()]),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn elems(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Scalar f32 value (rank-0 or single-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Validate against a manifest spec (shape + dtype).
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "shape mismatch: tensor {:?} vs spec {:?}",
                self.shape(),
                spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!("dtype mismatch: {:?} vs {:?}", self.dtype(), spec.dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.elems(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_f32(2.5);
        assert_eq!(s.scalar().unwrap(), 2.5);
        assert_eq!(s.shape(), &[] as &[usize]);
    }

    #[test]
    fn check_against_spec() {
        let spec = TensorSpec {
            shape: vec![4],
            dtype: Dtype::F32,
        };
        assert!(HostTensor::f32(vec![4], vec![0.0; 4]).check(&spec).is_ok());
        assert!(HostTensor::f32(vec![2, 2], vec![0.0; 4]).check(&spec).is_err());
        assert!(HostTensor::i32(vec![4], vec![0; 4]).check(&spec).is_err());
        let z = HostTensor::zeros(&spec);
        assert_eq!(z.elems(), 4);
    }
}
