//! Compute service: thread-confined PJRT engine behind a channel API.
//!
//! `PjRtClient` is `Rc`-based and must stay on one thread; worker threads
//! (one per simulated GPU) instead hold a cloneable [`ComputeClient`] and
//! submit `(executable key, host tensors)` calls. The service thread owns
//! the [`Engine`], executes requests in arrival order, and replies through
//! a per-call channel.
//!
//! This mirrors the physical testbed faithfully: the CPU is one shared
//! device, XLA parallelises *inside* an execution via its own thread pool,
//! and the coordinator's threads contend for it exactly like the paper's
//! GPUs contend for their own SMs. Throughput accounting at Layer 3 is
//! unaffected (it counts steps, not device-parallel speedup).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::manifest::Manifest;
use super::tensor::HostTensor;

enum Req {
    Run {
        key: String,
        inputs: Vec<HostTensor>,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    /// Compile additional executables of an arch (batch-size control may
    /// need a grad variant that was not preloaded).
    Load {
        arch: String,
        names: Vec<String>,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct ComputeClient {
    tx: Sender<Req>,
}

impl ComputeClient {
    /// Execute `key` (format `"{arch}/{exec}"`) with `inputs`.
    pub fn run(&self, key: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Run {
                key: key.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("compute service is down"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }

    /// Ensure `names` of `arch` are compiled.
    pub fn load(&self, arch: &str, names: &[&str]) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Load {
                arch: arch.to_string(),
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| anyhow!("compute service is down"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }
}

/// The running service (owns the engine thread).
pub struct ComputeService {
    tx: Sender<Req>,
    join: Option<JoinHandle<()>>,
}

impl ComputeService {
    /// Start the engine thread, compiling `preload` executables of `arch`
    /// up front. Compilation errors surface here, not at first use.
    pub fn start(manifest: Manifest, arch: &str, preload: &[&str]) -> Result<Self> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let arch_name = arch.to_string();
        let preload: Vec<String> = preload.iter().map(|s| s.to_string()).collect();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_thread(manifest, arch_name, preload, rx, ready_tx))
            .map_err(|e| anyhow!("spawning engine thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Self {
            tx,
            join: Some(join),
        })
    }

    pub fn client(&self) -> ComputeClient {
        ComputeClient {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_thread(
    manifest: Manifest,
    arch: String,
    preload: Vec<String>,
    rx: Receiver<Req>,
    ready: Sender<Result<()>>,
) {
    let mut engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let setup = (|| -> Result<()> {
        let am = manifest.arch(&arch)?.clone();
        let names: Vec<&str> = preload.iter().map(|s| s.as_str()).collect();
        engine.load_execs(&manifest, &am, &names)
    })();
    let failed = setup.is_err();
    let _ = ready.send(setup);
    if failed {
        return;
    }

    while let Ok(req) = rx.recv() {
        match req {
            Req::Run { key, inputs, reply } => {
                let _ = reply.send(engine.run(&key, &inputs));
            }
            Req::Load { arch, names, reply } => {
                let result = (|| -> Result<()> {
                    let am = manifest.arch(&arch)?.clone();
                    let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                    engine.load_execs(&manifest, &am, &names)
                })();
                let _ = reply.send(result);
            }
            Req::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    #[test]
    fn multi_threaded_clients_share_the_engine() {
        let Ok(m) = Manifest::load(ARTIFACTS) else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let svc = ComputeService::start(m, "tiny", &["init"]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = svc.client();
                std::thread::spawn(move || {
                    let out = c
                        .run("tiny/init", vec![HostTensor::i32(vec![1], vec![i])])
                        .unwrap();
                    // checksum across all params (some tensors are
                    // zero-init regardless of seed, e.g. biases/beta)
                    out.iter()
                        .map(|t| t.as_f32().unwrap().iter().map(|x| *x as f64).sum::<f64>())
                        .sum::<f64>()
                })
            })
            .collect();
        let sums: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // different seeds -> different params
        assert!(sums.windows(2).any(|w| w[0] != w[1]), "{sums:?}");
    }

    #[test]
    fn lazy_load_after_start() {
        let Ok(m) = Manifest::load(ARTIFACTS) else { return };
        let svc = ComputeService::start(m, "tiny", &["init"]).unwrap();
        let c = svc.client();
        // grad not preloaded: load on demand, then it runs
        c.load("tiny", &["grad_b8_ls10"]).unwrap();
        let params = c
            .run("tiny/init", vec![HostTensor::i32(vec![1], vec![0])])
            .unwrap();
        let px = 16 * 16 * 3;
        let mut inputs = params;
        inputs.push(HostTensor::f32(vec![8, 16, 16, 3], vec![0.0; 8 * px]));
        inputs.push(HostTensor::i32(vec![8], vec![0; 8]));
        let out = c.run("tiny/grad_b8_ls10", inputs).unwrap();
        assert!(out[0].scalar().unwrap().is_finite());
    }

    #[test]
    fn unknown_preload_fails_at_start() {
        let Ok(m) = Manifest::load(ARTIFACTS) else { return };
        assert!(ComputeService::start(m, "tiny", &["nonexistent"]).is_err());
    }
}
