//! Compute service: a thread-confined [`ComputeBackend`] behind a channel
//! API.
//!
//! Backends may not be movable across threads (the PJRT client is
//! `Rc`-based), so the service owns one thread that *constructs* the
//! backend from a [`BackendSpec`] and then executes `(executable key, host
//! tensors)` requests in arrival order. Worker threads (one per simulated
//! GPU) hold a cloneable [`ComputeClient`] and reply channels.
//!
//! This mirrors the physical testbed faithfully: the CPU is one shared
//! device, the backend parallelises *inside* an execution if it wants to,
//! and the coordinator's threads contend for it exactly like the paper's
//! GPUs contend for their own SMs. Throughput accounting at Layer 3 is
//! unaffected (it counts steps, not device-parallel speedup).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::backend::{BackendSpec, ComputeBackend};
use super::manifest::Manifest;
use super::tensor::HostTensor;

enum Req {
    Run {
        key: String,
        inputs: Vec<HostTensor>,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    /// Make additional executables of an arch available (batch-size control
    /// may need a grad variant that was not preloaded).
    Load {
        arch: String,
        names: Vec<String>,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the backend thread.
#[derive(Clone)]
pub struct ComputeClient {
    tx: Sender<Req>,
}

impl ComputeClient {
    /// Execute `key` (format `"{arch}/{exec}"`) with `inputs`.
    pub fn run(&self, key: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Run {
                key: key.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("compute service is down"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }

    /// Ensure `names` of `arch` are available.
    pub fn load(&self, arch: &str, names: &[&str]) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Load {
                arch: arch.to_string(),
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| anyhow!("compute service is down"))?;
        rx.recv().map_err(|_| anyhow!("compute service dropped reply"))?
    }
}

/// The running service (owns the backend thread).
pub struct ComputeService {
    tx: Sender<Req>,
    join: Option<JoinHandle<()>>,
}

impl ComputeService {
    /// Start the backend thread, instantiating `spec` over `manifest` and
    /// preparing `preload` executables of `arch` up front. Construction and
    /// preload errors surface here, not at first use.
    pub fn start(
        spec: BackendSpec,
        manifest: Manifest,
        arch: &str,
        preload: &[&str],
    ) -> Result<Self> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let arch_name = arch.to_string();
        let preload: Vec<String> = preload.iter().map(|s| s.to_string()).collect();
        let join = std::thread::Builder::new()
            .name("compute-backend".into())
            .spawn(move || backend_thread(spec, manifest, arch_name, preload, rx, ready_tx))
            .map_err(|e| anyhow!("spawning backend thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("backend thread died during startup"))??;
        Ok(Self {
            tx,
            join: Some(join),
        })
    }

    pub fn client(&self) -> ComputeClient {
        ComputeClient {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn backend_thread(
    spec: BackendSpec,
    manifest: Manifest,
    arch: String,
    preload: Vec<String>,
    rx: Receiver<Req>,
    ready: Sender<Result<()>>,
) {
    let mut backend: Box<dyn ComputeBackend> = match spec.instantiate(manifest) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let names: Vec<&str> = preload.iter().map(|s| s.as_str()).collect();
    let setup = backend.load(&arch, &names);
    let failed = setup.is_err();
    let _ = ready.send(setup);
    if failed {
        return;
    }

    while let Ok(req) = rx.recv() {
        match req {
            Req::Run { key, inputs, reply } => {
                let _ = reply.send(backend.run(&key, &inputs));
            }
            Req::Load { arch, names, reply } => {
                let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                let _ = reply.send(backend.load(&arch, &names));
            }
            Req::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::builtin_manifest;

    fn start(preload: &[&str]) -> Result<ComputeService> {
        ComputeService::start(BackendSpec::Reference, builtin_manifest(), "tiny", preload)
    }

    #[test]
    fn multi_threaded_clients_share_the_backend() {
        let svc = start(&["init"]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = svc.client();
                std::thread::spawn(move || {
                    let out = c
                        .run("tiny/init", vec![HostTensor::i32(vec![1], vec![i])])
                        .unwrap();
                    // checksum across all params (some tensors are
                    // zero-init regardless of seed, e.g. beta/bias)
                    out.iter()
                        .map(|t| {
                            t.as_f32()
                                .unwrap()
                                .iter()
                                .map(|x| f64::from(*x))
                                .sum::<f64>()
                        })
                        .sum::<f64>()
                })
            })
            .collect();
        let sums: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // different seeds -> different params
        assert!(sums.windows(2).any(|w| w[0] != w[1]), "{sums:?}");
    }

    #[test]
    fn lazy_load_after_start() {
        let svc = start(&["init"]).unwrap();
        let c = svc.client();
        // grad not preloaded: load on demand, then it runs
        c.load("tiny", &["grad_b8_ls10"]).unwrap();
        let params = c
            .run("tiny/init", vec![HostTensor::i32(vec![1], vec![0])])
            .unwrap();
        let px = 16 * 16 * 3;
        let mut inputs = params;
        inputs.push(HostTensor::f32(vec![8, 16, 16, 3], vec![0.0; 8 * px]));
        inputs.push(HostTensor::i32(vec![8], vec![0; 8]));
        let out = c.run("tiny/grad_b8_ls10", inputs).unwrap();
        assert!(out[0].scalar().unwrap().is_finite());
    }

    #[test]
    fn unknown_preload_fails_at_start() {
        assert!(start(&["nonexistent"]).is_err());
    }
}
