//! Compute pool: N thread-confined [`ComputeBackend`] lanes behind one
//! channel API.
//!
//! Backends may not be movable across threads (the PJRT client is
//! `Rc`-based), so the pool owns one thread **per lane**; each lane
//! *constructs* its own backend from a [`BackendSpec`] and then executes
//! requests in arrival order. Worker threads (one per simulated GPU) hold a
//! cloneable [`ComputeClient`] and pin their resident state to one lane, so
//! ranks execute `grad_step`/`apply` **concurrently** — adding workers adds
//! parallel compute, mirroring the paper's one-GPU-per-rank testbed instead
//! of serialising the whole cluster through a single device.
//!
//! Resident state ([`StateRef`]) lives inside a lane's backend: the
//! steady-state step ships only the batch in and the loss/grads/BN stats
//! out ([`ComputeClient::grad_step`]), then the reduced gradient and three
//! scalars ([`ComputeClient::apply`]). Parameters cross the channel only at
//! phase boundaries via [`ComputeClient::import_state`] /
//! [`ComputeClient::export_state`].
//!
//! The **overlapped** step uses [`ComputeClient::grad_step_streaming`]
//! instead: the lane pushes each parameter gradient down a channel in
//! reverse layer order while its backward pass is still running, the
//! worker all-reduces completed buckets concurrently, and queues
//! per-bucket [`ComputeClient::apply_partial_async`] updates behind the
//! stream (lane FIFO order makes that race-free by construction).
//!
//! Stateless calls (`init`, `eval_*` with caller-held params) go through
//! [`ComputeClient::run`] on lane 0; [`ComputeClient::load`] broadcasts to
//! every lane so batch-size control can lazily materialise a grad variant
//! pool-wide.
//!
//! [`PoolStats`] counts in-flight requests across lanes; its
//! `max_concurrent` watermark is how tests *observe* that different ranks'
//! compute really overlaps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use super::backend::{ApplyParams, BackendSpec, ComputeBackend, StateId};
use super::manifest::Manifest;
use super::tensor::HostTensor;

enum Req {
    Run {
        key: String,
        inputs: Vec<HostTensor>,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    /// Make additional executables of an arch available (batch-size control
    /// may need a grad variant that was not preloaded).
    Load {
        arch: String,
        names: Vec<String>,
        reply: Sender<Result<()>>,
    },
    CreateState {
        arch: String,
        seed: i32,
        reply: Sender<Result<StateId>>,
    },
    ImportState {
        arch: String,
        params: Vec<HostTensor>,
        momenta: Vec<HostTensor>,
        reply: Sender<Result<StateId>>,
    },
    ExportState {
        state: StateId,
        reply: Sender<Result<(Vec<HostTensor>, Vec<HostTensor>)>>,
    },
    DropState {
        state: StateId,
        reply: Sender<Result<()>>,
    },
    GradStep {
        state: StateId,
        exec: String,
        images: HostTensor,
        labels: HostTensor,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    /// Streaming grad: each parameter gradient is pushed down `grads` in
    /// reverse layer order as the backward pass produces it; the terminal
    /// reply carries `[loss, bn_stats..]` plus the batch tensors handed
    /// back so the caller can reuse their storage next step.
    GradStepStreaming {
        state: StateId,
        exec: String,
        images: HostTensor,
        labels: HostTensor,
        grads: Sender<(usize, HostTensor)>,
        reply: Sender<Result<(Vec<HostTensor>, HostTensor, HostTensor)>>,
    },
    Apply {
        state: StateId,
        grads: Vec<HostTensor>,
        hp: ApplyParams,
        reply: Sender<Result<()>>,
    },
    /// LARS update of params `[first_param, first_param + grads.len())`
    /// only — one bucket of the overlapped reduction pipeline.
    ApplyPartial {
        state: StateId,
        first_param: usize,
        grads: Vec<HostTensor>,
        hp: ApplyParams,
        reply: Sender<Result<()>>,
    },
    EvalStep {
        state: StateId,
        exec: String,
        bn_running: Vec<HostTensor>,
        images: HostTensor,
        labels: HostTensor,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    Shutdown,
}

/// In-flight **compute** accounting across all lanes of a pool. Only
/// `grad_step` / `apply` / `eval_step` requests are counted — bookkeeping
/// traffic (state import/export, loads) is excluded so the watermark can't
/// be satisfied by four ranks importing state at a phase boundary.
///
/// `max_concurrent` is a high-water mark: the largest number of compute
/// requests that were being *executed* (not queued) at the same instant.
/// With one lane it can never exceed 1; with N lanes and N busy ranks it
/// approaches N — the observable proof that the pool actually parallelises
/// compute.
#[derive(Debug, Default)]
pub struct PoolStats {
    active: AtomicUsize,
    max_concurrent: AtomicUsize,
    completed: AtomicUsize,
}

impl PoolStats {
    fn enter(&self) {
        let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_concurrent.fetch_max(now, Ordering::SeqCst);
    }

    fn exit(&self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
        self.completed.fetch_add(1, Ordering::SeqCst);
    }

    /// Highest number of simultaneously-executing compute requests
    /// (`grad_step`/`apply`/`eval_step`) observed.
    pub fn max_concurrent(&self) -> usize {
        self.max_concurrent.load(Ordering::SeqCst)
    }

    /// Total compute requests completed across all lanes.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::SeqCst)
    }

    /// Reset the watermark and counters (between test attempts).
    pub fn reset(&self) {
        self.max_concurrent.store(0, Ordering::SeqCst);
        self.completed.store(0, Ordering::SeqCst);
    }
}

/// Handle to one resident state: which lane owns it + the backend's id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StateRef {
    lane: usize,
    id: StateId,
}

impl StateRef {
    /// The lane (backend instance) this state is pinned to.
    pub fn lane(&self) -> usize {
        self.lane
    }
}

/// A not-yet-collected reply from a lane. Lets the caller queue several
/// requests (per-bucket applies) and keep working while the lane drains
/// them; errors surface at [`Pending::wait`].
#[derive(Debug)]
pub struct Pending<T> {
    rx: Receiver<Result<T>>,
    lane: usize,
}

impl<T> Pending<T> {
    /// Block until the lane replies.
    pub fn wait(self) -> Result<T> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("compute lane {} dropped reply", self.lane))?
    }
}

/// One in-flight streaming gradient computation
/// ([`ComputeClient::grad_step_streaming`]). Gradients arrive on
/// [`GradStream::recv_grad`] in strictly decreasing parameter order while
/// the lane's backward pass runs; [`GradStream::finish`] collects the
/// terminal `[loss, bn_stats..]` reply — plus the batch tensors handed
/// back for storage reuse — and surfaces any backend error.
#[derive(Debug)]
pub struct GradStream {
    grads: Receiver<(usize, HostTensor)>,
    reply: Receiver<Result<(Vec<HostTensor>, HostTensor, HostTensor)>>,
    lane: usize,
}

impl GradStream {
    /// Blocking receive of the next gradient. `None` once the backend has
    /// emitted everything (or failed — `finish` tells which).
    pub fn recv_grad(&self) -> Option<(usize, HostTensor)> {
        self.grads.recv().ok()
    }

    /// Non-blocking receive: whatever the backend has already produced.
    pub fn try_recv_grad(&self) -> Option<(usize, HostTensor)> {
        self.grads.try_recv().ok()
    }

    /// Wait for the terminal reply: `([loss, bn_stats..], images, labels)`.
    pub fn finish(self) -> Result<(Vec<HostTensor>, HostTensor, HostTensor)> {
        self.reply
            .recv()
            .map_err(|_| anyhow!("compute lane {} dropped streaming reply", self.lane))?
    }
}

/// Cloneable, `Send` handle to the lane threads.
#[derive(Clone)]
pub struct ComputeClient {
    lanes: Arc<Vec<Sender<Req>>>,
    stats: Arc<PoolStats>,
}

impl ComputeClient {
    /// Number of lanes (independent backend instances) in the pool.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Shared in-flight stats (concurrency watermark).
    pub fn stats(&self) -> Arc<PoolStats> {
        self.stats.clone()
    }

    fn lane(&self, lane: usize) -> Result<&Sender<Req>> {
        self.lanes
            .get(lane)
            .ok_or_else(|| anyhow!("lane {lane} out of range (pool has {})", self.lanes.len()))
    }

    fn request<T>(
        &self,
        lane: usize,
        make: impl FnOnce(Sender<Result<T>>) -> Req,
    ) -> Result<T> {
        let (reply, rx) = channel();
        self.lane(lane)?
            .send(make(reply))
            .map_err(|_| anyhow!("compute lane {lane} is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("compute lane {lane} dropped reply"))?
    }

    /// Execute `key` (format `"{arch}/{exec}"`) with `inputs` on lane 0
    /// (stateless entry points: `init`, caller-held-params eval).
    pub fn run(&self, key: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let key = key.to_string();
        self.request(0, move |reply| Req::Run { key, inputs, reply })
    }

    /// Ensure `names` of `arch` are available **on every lane**.
    pub fn load(&self, arch: &str, names: &[&str]) -> Result<()> {
        let names: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        for lane in 0..self.lanes.len() {
            let arch = arch.to_string();
            let names = names.clone();
            self.request(lane, move |reply| Req::Load { arch, names, reply })?;
        }
        Ok(())
    }

    /// Create a fresh resident state (`init(seed)`, zero momenta) on `lane`.
    pub fn create_state(&self, lane: usize, arch: &str, seed: i32) -> Result<StateRef> {
        let arch = arch.to_string();
        let id = self.request(lane, move |reply| Req::CreateState { arch, seed, reply })?;
        Ok(StateRef { lane, id })
    }

    /// Pin an existing `(params, momenta)` pair to `lane` as resident state.
    pub fn import_state(
        &self,
        lane: usize,
        arch: &str,
        params: Vec<HostTensor>,
        momenta: Vec<HostTensor>,
    ) -> Result<StateRef> {
        let arch = arch.to_string();
        let id = self.request(lane, move |reply| Req::ImportState {
            arch,
            params,
            momenta,
            reply,
        })?;
        Ok(StateRef { lane, id })
    }

    /// **Move** a resident state out: `(params, momenta)`. Consumes the
    /// handle — the lane-side state is removed (zero-copy on the backend),
    /// so a continuing phase must `import_state` the tensors again.
    pub fn export_state(&self, state: StateRef) -> Result<(Vec<HostTensor>, Vec<HostTensor>)> {
        let id = state.id;
        self.request(state.lane, move |reply| Req::ExportState { state: id, reply })
    }

    /// Release a resident state without reading it back.
    pub fn drop_state(&self, state: StateRef) -> Result<()> {
        let id = state.id;
        self.request(state.lane, move |reply| Req::DropState { state: id, reply })
    }

    /// One local gradient computation against the resident parameters:
    /// `[loss, grads.., bn_stats..]`.
    pub fn grad_step(
        &self,
        state: &StateRef,
        exec: &str,
        images: HostTensor,
        labels: HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let id = state.id;
        let exec = exec.to_string();
        self.request(state.lane, move |reply| Req::GradStep {
            state: id,
            exec,
            images,
            labels,
            reply,
        })
    }

    /// LARS update of the resident state in place from reduced gradients.
    pub fn apply(&self, state: &StateRef, grads: Vec<HostTensor>, hp: ApplyParams) -> Result<()> {
        let id = state.id;
        self.request(state.lane, move |reply| Req::Apply {
            state: id,
            grads,
            hp,
            reply,
        })
    }

    /// Start a streaming gradient computation: returns immediately with a
    /// [`GradStream`]; the lane pushes gradients down it in reverse layer
    /// order as backprop produces them, so the caller can all-reduce early
    /// buckets while later ones are still being computed.
    pub fn grad_step_streaming(
        &self,
        state: &StateRef,
        exec: &str,
        images: HostTensor,
        labels: HostTensor,
    ) -> Result<GradStream> {
        let (gtx, grx) = channel();
        let (rtx, rrx) = channel();
        self.lane(state.lane)?
            .send(Req::GradStepStreaming {
                state: state.id,
                exec: exec.to_string(),
                images,
                labels,
                grads: gtx,
                reply: rtx,
            })
            .map_err(|_| anyhow!("compute lane {} is down", state.lane))?;
        Ok(GradStream {
            grads: grx,
            reply: rrx,
            lane: state.lane,
        })
    }

    /// Queue a LARS update of one contiguous parameter slice (a bucket)
    /// without waiting for it; collect the result via [`Pending::wait`].
    /// Lane requests execute in FIFO order, so buckets queued behind an
    /// in-flight streaming grad run only after the backward pass finishes
    /// — the update can never race the gradient computation.
    pub fn apply_partial_async(
        &self,
        state: &StateRef,
        first_param: usize,
        grads: Vec<HostTensor>,
        hp: ApplyParams,
    ) -> Result<Pending<()>> {
        let (rtx, rrx) = channel();
        self.lane(state.lane)?
            .send(Req::ApplyPartial {
                state: state.id,
                first_param,
                grads,
                hp,
                reply: rtx,
            })
            .map_err(|_| anyhow!("compute lane {} is down", state.lane))?;
        Ok(Pending {
            rx: rrx,
            lane: state.lane,
        })
    }


    /// Evaluation forward pass against the resident parameters with the
    /// synchronized running BN statistics: `[loss_sum, n_correct]`.
    pub fn eval_step(
        &self,
        state: &StateRef,
        exec: &str,
        bn_running: &[HostTensor],
        images: HostTensor,
        labels: HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let id = state.id;
        let exec = exec.to_string();
        let bn_running = bn_running.to_vec();
        self.request(state.lane, move |reply| Req::EvalStep {
            state: id,
            exec,
            bn_running,
            images,
            labels,
            reply,
        })
    }
}

/// The running pool (owns the lane threads).
pub struct ComputeService {
    lanes: Vec<Sender<Req>>,
    stats: Arc<PoolStats>,
    joins: Vec<JoinHandle<()>>,
}

impl ComputeService {
    /// Single-lane pool: the serialized configuration (all ranks share one
    /// backend thread). Construction and preload errors surface here.
    pub fn start(
        spec: BackendSpec,
        manifest: Manifest,
        arch: &str,
        preload: &[&str],
    ) -> Result<Self> {
        Self::start_pool(spec, manifest, arch, preload, 1)
    }

    /// Start `lanes` backend threads, each instantiating `spec` over its
    /// own copy of `manifest` and preparing `preload` executables of `arch`
    /// up front. Construction and preload errors surface here, not at first
    /// use.
    pub fn start_pool(
        spec: BackendSpec,
        manifest: Manifest,
        arch: &str,
        preload: &[&str],
        lanes: usize,
    ) -> Result<Self> {
        if lanes == 0 {
            bail!("compute pool needs at least one lane");
        }
        let stats = Arc::new(PoolStats::default());
        let preload: Vec<String> = preload.iter().map(|s| s.to_string()).collect();
        let mut txs = Vec::with_capacity(lanes);
        let mut joins = Vec::with_capacity(lanes);
        let mut readies = Vec::with_capacity(lanes);
        // Spawn every lane first, then drain readiness: construction +
        // preload (HLO compilation under PJRT) is independent per lane, so
        // the lanes set themselves up concurrently instead of paying N
        // startups back-to-back.
        for lane in 0..lanes {
            let (tx, rx) = channel::<Req>();
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let manifest = manifest.clone();
            let arch_name = arch.to_string();
            let preload = preload.clone();
            let stats = stats.clone();
            let join = std::thread::Builder::new()
                .name(format!("compute-lane{lane}"))
                .spawn(move || lane_thread(spec, manifest, arch_name, preload, rx, ready_tx, stats))
                .map_err(|e| anyhow!("spawning compute lane {lane}: {e}"))?;
            txs.push(tx);
            joins.push(join);
            readies.push(ready_rx);
        }
        for (lane, ready_rx) in readies.into_iter().enumerate() {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("compute lane {lane} died during startup"))??;
        }
        Ok(Self {
            lanes: txs,
            stats,
            joins,
        })
    }

    pub fn client(&self) -> ComputeClient {
        ComputeClient {
            lanes: Arc::new(self.lanes.clone()),
            stats: self.stats.clone(),
        }
    }

    /// Number of lanes in the pool.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Pool-wide in-flight stats (concurrency watermark).
    pub fn stats(&self) -> Arc<PoolStats> {
        self.stats.clone()
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        for tx in &self.lanes {
            let _ = tx.send(Req::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn lane_thread(
    spec: BackendSpec,
    manifest: Manifest,
    arch: String,
    preload: Vec<String>,
    rx: Receiver<Req>,
    ready: Sender<Result<()>>,
    stats: Arc<PoolStats>,
) {
    let mut backend: Box<dyn ComputeBackend> = match spec.instantiate(manifest) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let names: Vec<&str> = preload.iter().map(|s| s.as_str()).collect();
    let setup = backend.load(&arch, &names);
    let failed = setup.is_err();
    let _ = ready.send(setup);
    if failed {
        return;
    }

    while let Ok(req) = rx.recv() {
        if matches!(req, Req::Shutdown) {
            break;
        }
        // Only actual compute counts toward the concurrency watermark;
        // state/bookkeeping traffic would make the overlap signal vacuous
        // (every rank imports state simultaneously at phase entry).
        let is_compute = matches!(
            req,
            Req::GradStep { .. }
                | Req::GradStepStreaming { .. }
                | Req::Apply { .. }
                | Req::ApplyPartial { .. }
                | Req::EvalStep { .. }
        );
        if is_compute {
            stats.enter();
        }
        match req {
            Req::Run { key, inputs, reply } => {
                let _ = reply.send(backend.run(&key, &inputs));
            }
            Req::Load { arch, names, reply } => {
                let names: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                let _ = reply.send(backend.load(&arch, &names));
            }
            Req::CreateState { arch, seed, reply } => {
                let _ = reply.send(backend.create_state(&arch, seed));
            }
            Req::ImportState {
                arch,
                params,
                momenta,
                reply,
            } => {
                let _ = reply.send(backend.import_state(&arch, params, momenta));
            }
            Req::ExportState { state, reply } => {
                let _ = reply.send(backend.export_state(state));
            }
            Req::DropState { state, reply } => {
                let _ = reply.send(backend.drop_state(state));
            }
            Req::GradStep {
                state,
                exec,
                images,
                labels,
                reply,
            } => {
                let _ = reply.send(backend.grad_step(state, &exec, &images, &labels));
            }
            Req::GradStepStreaming {
                state,
                exec,
                images,
                labels,
                grads,
                reply,
            } => {
                let res = backend.grad_step_streaming(state, &exec, &images, &labels, &mut |i, t| {
                    // A hung-up receiver just means the worker gave up on
                    // this step; the terminal reply carries the real error
                    // state, so drops here are ignored.
                    let _ = grads.send((i, t));
                });
                // Close the gradient stream before the terminal reply so a
                // draining caller observes: grads end, then the reply.
                drop(grads);
                let _ = reply.send(res.map(|outs| (outs, images, labels)));
            }
            Req::Apply {
                state,
                grads,
                hp,
                reply,
            } => {
                let _ = reply.send(backend.apply(state, &grads, hp));
            }
            Req::ApplyPartial {
                state,
                first_param,
                grads,
                hp,
                reply,
            } => {
                let _ = reply.send(backend.apply_partial(state, first_param, grads, hp));
            }
            Req::EvalStep {
                state,
                exec,
                bn_running,
                images,
                labels,
                reply,
            } => {
                let _ = reply.send(backend.eval_step(state, &exec, &bn_running, &images, &labels));
            }
            Req::Shutdown => unreachable!("handled above"),
        }
        if is_compute {
            stats.exit();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::builtin_manifest;

    fn start(preload: &[&str]) -> Result<ComputeService> {
        ComputeService::start(BackendSpec::Reference, builtin_manifest(), "tiny", preload)
    }

    fn start_pool(preload: &[&str], lanes: usize) -> Result<ComputeService> {
        ComputeService::start_pool(
            BackendSpec::Reference,
            builtin_manifest(),
            "tiny",
            preload,
            lanes,
        )
    }

    fn batch_tensors(b: usize, fill: f32) -> (HostTensor, HostTensor) {
        let px = 16 * 16 * 3;
        (
            HostTensor::f32(vec![b, 16, 16, 3], vec![fill; b * px]),
            HostTensor::i32(vec![b], (0..b as i32).map(|i| i % 10).collect()),
        )
    }

    #[test]
    fn multi_threaded_clients_share_the_backend() {
        let svc = start(&["init"]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = svc.client();
                std::thread::spawn(move || {
                    let out = c
                        .run("tiny/init", vec![HostTensor::i32(vec![1], vec![i])])
                        .unwrap();
                    // checksum across all params (some tensors are
                    // zero-init regardless of seed, e.g. beta/bias)
                    out.iter()
                        .map(|t| {
                            t.as_f32()
                                .unwrap()
                                .iter()
                                .map(|x| f64::from(*x))
                                .sum::<f64>()
                        })
                        .sum::<f64>()
                })
            })
            .collect();
        let sums: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // different seeds -> different params
        assert!(sums.windows(2).any(|w| w[0] != w[1]), "{sums:?}");
    }

    #[test]
    fn lazy_load_after_start() {
        let svc = start(&["init"]).unwrap();
        let c = svc.client();
        // grad not preloaded: load on demand, then it runs
        c.load("tiny", &["grad_b8_ls10"]).unwrap();
        let params = c
            .run("tiny/init", vec![HostTensor::i32(vec![1], vec![0])])
            .unwrap();
        let px = 16 * 16 * 3;
        let mut inputs = params;
        inputs.push(HostTensor::f32(vec![8, 16, 16, 3], vec![0.0; 8 * px]));
        inputs.push(HostTensor::i32(vec![8], vec![0; 8]));
        let out = c.run("tiny/grad_b8_ls10", inputs).unwrap();
        assert!(out[0].scalar().unwrap().is_finite());
    }

    #[test]
    fn unknown_preload_fails_at_start() {
        assert!(start(&["nonexistent"]).is_err());
    }

    #[test]
    fn zero_lanes_is_an_error() {
        assert!(start_pool(&["init"], 0).is_err());
    }

    #[test]
    fn state_round_trips_across_lanes() {
        // import on lane 0, export, re-import on lane 1 (a *different*
        // backend instance), export again: byte-identical both hops — the
        // BSC worker-count-change handoff invariant.
        let svc = start_pool(&["init", "grad_b8_ls10", "apply"], 2).unwrap();
        let c = svc.client();
        let s0 = c.create_state(0, "tiny", 33).unwrap();
        // move the state off init so the round trip covers trained values
        let (img, lab) = batch_tensors(8, 0.25);
        let out = c.grad_step(&s0, "grad_b8_ls10", img, lab).unwrap();
        let n_params = out.len() - 1 - 7; // loss + params + 7 bn layers
        c.apply(
            &s0,
            out[1..1 + n_params].to_vec(),
            ApplyParams {
                lr: 0.4,
                momentum: 0.9,
                weight_decay: 5e-5,
            },
        )
        .unwrap();
        let (p0, m0) = c.export_state(s0).unwrap();
        let s1 = c.import_state(1, "tiny", p0.clone(), m0.clone()).unwrap();
        assert_eq!(s1.lane(), 1);
        let (p1, m1) = c.export_state(s1).unwrap();
        assert_eq!(p0, p1);
        assert_eq!(m0, m1);
        // export moves the state out: both handles are dead now
        assert!(c.drop_state(s0).is_err());
        assert!(c.drop_state(s1).is_err());
        // drop_state releases without reading back
        let s2 = c.import_state(0, "tiny", p0, m0).unwrap();
        c.drop_state(s2).unwrap();
        assert!(c.export_state(s2).is_err());
    }

    /// Streaming grad + per-bucket async applies through the pool must be
    /// bit-identical to the blocking grad_step + whole-model apply: same
    /// gradients (in strictly decreasing param order), same loss, and the
    /// same resident state afterwards. The batch tensors ride back in the
    /// terminal reply for storage reuse.
    #[test]
    fn streaming_pipeline_matches_blocking_path_bitwise() {
        let svc = start_pool(&["init", "grad_b8_ls10"], 2).unwrap();
        let c = svc.client();
        let s_stream = c.create_state(1, "tiny", 11).unwrap();
        let s_block = c.create_state(0, "tiny", 11).unwrap();

        let (img, lab) = batch_tensors(8, 0.3);
        let full = c.grad_step(&s_block, "grad_b8_ls10", img, lab).unwrap();
        let n_params = full.len() - 1 - 7;

        let (img, lab) = batch_tensors(8, 0.3);
        let stream = c
            .grad_step_streaming(&s_stream, "grad_b8_ls10", img, lab)
            .unwrap();
        let mut got: Vec<(usize, HostTensor)> = Vec::new();
        while let Some(g) = stream.recv_grad() {
            got.push(g);
        }
        let (outs, img_back, lab_back) = stream.finish().unwrap();
        assert_eq!(img_back.elems(), 8 * 16 * 16 * 3, "images handed back");
        assert_eq!(lab_back.elems(), 8, "labels handed back");
        assert_eq!(got.len(), n_params);
        assert!(got.windows(2).all(|w| w[0].0 > w[1].0), "reverse order");
        for (i, t) in &got {
            assert_eq!(t, &full[1 + i], "gradient #{i} diverged");
        }
        assert_eq!(outs[0], full[0], "loss diverged");
        assert_eq!(&outs[1..], &full[1 + n_params..], "bn stats diverged");

        // per-bucket async applies == one whole-model apply, bitwise
        let hp = ApplyParams {
            lr: 0.3,
            momentum: 0.9,
            weight_decay: 5e-5,
        };
        got.sort_by_key(|(i, _)| *i);
        let grads: Vec<HostTensor> = got.into_iter().map(|(_, t)| t).collect();
        let split = n_params / 2;
        let p1 = c
            .apply_partial_async(&s_stream, 0, grads[..split].to_vec(), hp)
            .unwrap();
        let p2 = c
            .apply_partial_async(&s_stream, split, grads[split..].to_vec(), hp)
            .unwrap();
        p1.wait().unwrap();
        p2.wait().unwrap();
        c.apply(&s_block, grads, hp).unwrap();

        let (ps, ms) = c.export_state(s_stream).unwrap();
        let (pb, mb) = c.export_state(s_block).unwrap();
        assert_eq!(ps, pb, "params diverged after bucketed apply");
        assert_eq!(ms, mb, "momenta diverged after bucketed apply");
    }

    #[test]
    fn lanes_match_single_lane_bitwise() {
        // Same seed + same batch schedule driven through a 1-lane pool and
        // a 4-lane pool (one rank per lane) must end bit-identical: the
        // multi-lane refactor may not change numerics.
        let run = |lanes: usize| -> Vec<(Vec<HostTensor>, Vec<HostTensor>)> {
            let svc = start_pool(&["init", "grad_b8_ls10", "apply"], lanes).unwrap();
            let c = svc.client();
            let handles: Vec<_> = (0..4usize)
                .map(|rank| {
                    let c = c.clone();
                    std::thread::spawn(move || {
                        let lane = rank % c.lanes();
                        let s = c.create_state(lane, "tiny", 7).unwrap();
                        for step in 0..5 {
                            let (img, lab) = batch_tensors(8, 0.1 * (step as f32 + 1.0));
                            let out = c.grad_step(&s, "grad_b8_ls10", img, lab).unwrap();
                            let n_params = out.len() - 1 - 7;
                            c.apply(
                                &s,
                                out[1..1 + n_params].to_vec(),
                                ApplyParams {
                                    lr: 0.2,
                                    momentum: 0.9,
                                    weight_decay: 5e-5,
                                },
                            )
                            .unwrap();
                        }
                        c.export_state(s).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };
        let serial = run(1);
        let pooled = run(4);
        for (rank, (a, b)) in serial.iter().zip(&pooled).enumerate() {
            assert_eq!(a.0, b.0, "rank {rank} params diverged");
            assert_eq!(a.1, b.1, "rank {rank} momenta diverged");
        }
    }

    #[test]
    fn lanes_execute_concurrently() {
        // 4 rank threads on 4 lanes: the in-flight watermark must reach at
        // least 2 — grad/apply from different ranks genuinely overlap.
        // Retried because overlap is a scheduling property, not a logical
        // one; with 4 threads × 60 grad steps per attempt a miss on every
        // attempt is practically impossible.
        let svc = start_pool(&["init", "grad_b32_ls10"], 4).unwrap();
        let stats = svc.stats();
        for attempt in 0..20 {
            stats.reset();
            let c = svc.client();
            let handles: Vec<_> = (0..4usize)
                .map(|rank| {
                    let c = c.clone();
                    std::thread::spawn(move || {
                        let s = c.create_state(rank, "tiny", rank as i32).unwrap();
                        for _ in 0..60 {
                            let (img, lab) = batch_tensors(32, 0.5);
                            c.grad_step(&s, "grad_b32_ls10", img, lab).unwrap();
                        }
                        c.drop_state(s).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            if stats.max_concurrent() >= 2 {
                return; // observed real overlap
            }
            eprintln!("attempt {attempt}: max_concurrent {}", stats.max_concurrent());
        }
        panic!("4 lanes never executed concurrently across 20 attempts");
    }
}
